//! Model registry + engine routing.
//!
//! A [`ModelVariant`] owns one or more engines for the same network (e.g.
//! the reordered streaming engine, the CSR layer-wise baseline, and the
//! XLA artifact). The router picks the serving engine per the variant's
//! policy; the benches use explicit engine selection to compare them.

use crate::exec::fused::{FusionStats, SkipCounters};
use crate::exec::parallel::{ParallelEngine, ShardTimings};
use crate::exec::quant::ErrorCertificate;
use crate::exec::simd::{self, Kernel};
use crate::exec::tiled::TiledStats;
use crate::exec::Engine;
use crate::ffnn::graph::Ffnn;
use crate::ffnn::topo::ConnOrder;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Structured rejection reasons of [`ModelVariant::build`] — the only
/// variant constructor the CLI, loadgen, benches, and registry go
/// through. Machine-matchable (no string parsing) and carries the knob
/// values that were rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum VariantError {
    /// `schedule` is not one of interp / fused / tiled.
    UnknownSchedule(String),
    /// `precision` is not one of f32 / i8.
    UnknownPrecision(String),
    /// The (schedule, precision) point is not available for this
    /// model's payload — e.g. a compiled schedule requested for a
    /// quant-stream payload, which only carries the interpreter's
    /// record format. Every point builds from a network or a `.sfb`
    /// artifact.
    Incompatible { schedule: String, precision: String },
    /// `fast_mem` was given for a schedule that has no fast-memory
    /// budget knob (only tiled does).
    FastMemRequiresTiled { schedule: String, fast_mem: usize },
    /// The schedule compiler itself rejected the network/budget (e.g. a
    /// sub-minimum tiled `M`).
    Compile { schedule: String, message: String },
    /// `kernel` is not one of auto / scalar / avx2.
    UnknownKernel(String),
    /// An explicit non-scalar `kernel` was given for a schedule that has
    /// no microkernel layer (only the compiled schedules fused/tiled
    /// dispatch through `exec::simd`).
    KernelRequiresCompiled { schedule: String, kernel: String },
    /// An explicit `kernel` the CPU cannot execute (e.g. `avx2` on a
    /// machine without AVX2; `auto` never fails — it falls back).
    KernelUnsupported { kernel: String },
}

impl std::fmt::Display for VariantError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            VariantError::UnknownSchedule(s) => {
                write!(f, "unknown schedule {s:?} (expected interp, fused or tiled)")
            }
            VariantError::UnknownPrecision(p) => {
                write!(f, "unknown precision {p:?} (expected f32 or i8)")
            }
            VariantError::Incompatible { schedule, precision } => write!(
                f,
                "schedule {schedule:?} is not available at precision {precision:?} for this \
                 model's payload (see the composition matrix in README.md)"
            ),
            VariantError::FastMemRequiresTiled { schedule, fast_mem } => write!(
                f,
                "--fast-mem {fast_mem} only applies to --schedule tiled (got schedule \
                 {schedule:?})"
            ),
            VariantError::Compile { schedule, message } => {
                write!(f, "compiling the {schedule} schedule failed: {message}")
            }
            VariantError::UnknownKernel(k) => {
                write!(f, "unknown kernel {k:?} (expected auto, scalar or avx2)")
            }
            VariantError::KernelRequiresCompiled { schedule, kernel } => write!(
                f,
                "--kernel {kernel} only applies to the compiled schedules fused and tiled \
                 (got schedule {schedule:?})"
            ),
            VariantError::KernelUnsupported { kernel } => write!(
                f,
                "kernel {kernel:?} is not supported by this CPU (use --kernel auto to \
                 pick the best supported path)"
            ),
        }
    }
}

/// Resolve the `--kernel` knob against the schedule and the CPU: `auto`
/// picks the best supported kernel for the compiled schedules (the only
/// ones with a microkernel layer) and tags everything else "scalar"; an
/// explicit `avx2` requires both a compiled schedule and runtime AVX2
/// support. Shared by [`ModelVariant::build`] and the model loader's
/// knob validation.
pub(crate) fn resolve_kernel_tag(
    schedule: &str,
    kernel: &str,
) -> Result<&'static str, VariantError> {
    let compiled = matches!(schedule, "fused" | "tiled");
    match kernel {
        "auto" if compiled => Ok(Kernel::auto().name()),
        "auto" | "scalar" => Ok("scalar"),
        "avx2" if !compiled => Err(VariantError::KernelRequiresCompiled {
            schedule: schedule.to_string(),
            kernel: kernel.to_string(),
        }),
        "avx2" if !simd::avx2_supported() => Err(VariantError::KernelUnsupported {
            kernel: kernel.to_string(),
        }),
        "avx2" => Ok("avx2"),
        other => Err(VariantError::UnknownKernel(other.to_string())),
    }
}

impl std::error::Error for VariantError {}

/// Engine-selection policy for a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always use the engine registered under this index.
    Fixed(usize),
    /// Use the density heuristic of the paper's Fig. 7: streaming wins
    /// for sparse networks, layer-wise CSR for dense ones. The variant
    /// stores the network density; below `0.5` → engine 0 (stream),
    /// else engine 1 (csr) if present.
    DensityHeuristic,
}

/// A registered model with its candidate engines.
#[derive(Clone)]
pub struct ModelVariant {
    pub name: String,
    pub engines: Vec<Arc<dyn Engine>>,
    pub policy: RoutePolicy,
    /// Edge density of the underlying network (for the heuristic).
    pub density: f64,
    /// Per-shard timing counters when the serving engine is a
    /// [`ParallelEngine`]; the server links these into its metrics.
    pub shard_timings: Option<Arc<ShardTimings>>,
    /// Numeric precision of the serving engine: "f32" (default) or
    /// "i8" (compressed quantized stream). Orthogonal to sharding.
    pub precision: &'static str,
    /// Op-stream schedule of the serving engine: "interp" (default, the
    /// per-connection stream interpreter), "fused" (the run-length
    /// block-compiled engine) or "tiled" (the cache-tiled slot-compiled
    /// engine). Orthogonal to sharding and precision (see the
    /// composition matrix in `exec`'s module docs).
    pub schedule: &'static str,
    /// Compile-time fusion statistics when the serving engine is a
    /// `FusedEngine`; the server surfaces these in `Metrics::snapshot`
    /// under `fusion.<model>`.
    pub fusion: Option<FusionStats>,
    /// Compile-time tiling statistics (segments, live sets, fills/spills
    /// per connection) when the serving engine is a `TiledEngine`; the
    /// server surfaces these in `Metrics::snapshot` under
    /// `tiled.<model>`.
    pub tiled: Option<TiledStats>,
    /// Run-time activation-skip counters when the serving engine is one
    /// of the compiled schedules: AxpyRuns checked, and skipped because
    /// the source activation row was entirely zero. The server surfaces
    /// these in `Metrics::snapshot` (merged into the `fusion.<model>` /
    /// `tiled.<model>` entries and standalone under `skips.<model>`).
    pub skips: Option<Arc<SkipCounters>>,
    /// Microkernel path the serving engine dispatches to: "scalar" (the
    /// portable reference — also what the interp schedule's
    /// per-connection loop amounts to) or "avx2" (`exec::simd` runtime
    /// dispatch on the compiled schedules). All kernels are
    /// bit-identical; the tag records which path serves, and the server
    /// surfaces it in `Metrics::snapshot` under `kernel.<model>`.
    pub kernel: &'static str,
    /// Batch shards of the serving engine (1 = serial). Together with
    /// `schedule`, `precision` and `kernel` this pins the point in the
    /// composition matrix; see [`ModelVariant::label`].
    pub workers: usize,
    /// One-line human description of the serving engine (set by
    /// [`ModelVariant::build`]; empty for hand-assembled variants).
    pub summary: String,
    /// Deploy-time certified accuracy bound vs the f32 reference when
    /// the serving engine is quantized (`precision == "i8"`). The
    /// overload control plane stamps `bound_for(‖x‖∞)` on degraded
    /// responses; `None` for exact (f32) engines.
    pub error_cert: Option<ErrorCertificate>,
}

impl ModelVariant {
    pub fn new(name: &str, engine: Arc<dyn Engine>) -> ModelVariant {
        ModelVariant {
            name: name.to_string(),
            engines: vec![engine],
            policy: RoutePolicy::Fixed(0),
            density: 0.0,
            shard_timings: None,
            precision: "f32",
            schedule: "interp",
            fusion: None,
            tiled: None,
            skips: None,
            kernel: "scalar",
            workers: 1,
            summary: String::new(),
            error_cert: None,
        }
    }

    /// Canonical variant label
    /// `"<schedule>-<precision>-w<workers>-<kernel>"` (e.g.
    /// `"fused-f32-w4-avx2"`) — the key the loadgen reports and the
    /// serving benches use to compare engine variants.
    pub fn label(&self) -> String {
        format!("{}-{}-w{}-{}", self.schedule, self.precision, self.workers, self.kernel)
    }

    /// Build a serving variant from the composition-matrix knobs shared
    /// by `sparseflow serve`, `sparseflow loadgen`, and the serving
    /// benches: `schedule` ∈ {interp, fused, tiled}, `precision` ∈
    /// {f32, i8} — every (schedule, precision) point builds; i8 with a
    /// compiled schedule runs the quant-fused/quant-tiled engines, whose
    /// macro-op pools are shared with the f32 compilation while the
    /// weight pool stays i8 with per-group dequant. `workers` > 1 wraps
    /// the engine in a batch-sharded [`ParallelEngine`]. `fast_mem` is
    /// the tiled schedule's fast-memory budget `M` in slots (0 =
    /// autotune through the I/O simulator); it is rejected for
    /// non-tiled schedules. `kernel` ∈ {auto, scalar, avx2} picks the
    /// `exec::simd` microkernel of the compiled schedules (auto = best
    /// the CPU supports; an explicit avx2 is rejected on CPUs without
    /// it, and on non-compiled schedules). Rejections come back as
    /// structured [`VariantError`] values. Activation-sparsity skipping
    /// is on; use [`ModelVariant::build_with_opts`] to disable it.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        name: &str,
        net: &Ffnn,
        order: &ConnOrder,
        schedule: &str,
        precision: &str,
        workers: usize,
        fast_mem: usize,
        kernel: &str,
    ) -> Result<ModelVariant, VariantError> {
        ModelVariant::build_with_opts(
            name, net, order, schedule, precision, workers, fast_mem, kernel, true,
        )
    }

    /// [`ModelVariant::build`] with explicit engine options: `skip`
    /// toggles activation-sparsity skipping on the compiled schedules
    /// (AxpyRuns over an all-zero source activation row are skipped
    /// wholesale; value-identical either way, so the knob exists for
    /// benchmarking and bisection — `--no-skip` on the CLI).
    #[allow(clippy::too_many_arguments)]
    pub fn build_with_opts(
        name: &str,
        net: &Ffnn,
        order: &ConnOrder,
        schedule: &str,
        precision: &str,
        workers: usize,
        fast_mem: usize,
        kernel: &str,
        skip: bool,
    ) -> Result<ModelVariant, VariantError> {
        use crate::exec::fused::FusedEngine;
        use crate::exec::quant::{
            QuantFusedEngine, QuantStreamEngine, QuantStreamProgram, QuantTiledEngine,
        };
        use crate::exec::stream::StreamingEngine;
        use crate::exec::tiled::{TiledEngine, TiledProgram};

        if fast_mem != 0 && schedule != "tiled" {
            return Err(VariantError::FastMemRequiresTiled {
                schedule: schedule.to_string(),
                fast_mem,
            });
        }
        let kernel_tag = resolve_kernel_tag(schedule, kernel)?;
        let k = if kernel_tag == "avx2" { Kernel::Avx2 } else { Kernel::Scalar };
        let compile_err = |e: anyhow::Error| VariantError::Compile {
            schedule: schedule.to_string(),
            message: e.to_string(),
        };
        let mut fusion = None;
        let mut tiled_stats = None;
        let mut skips: Option<Arc<SkipCounters>> = None;
        let skip_tag = if skip { "on" } else { "off" };
        let (engine, summary): (Arc<dyn Engine>, String) = match (precision, schedule) {
            ("f32", "interp") => (
                Arc::new(StreamingEngine::new(net, order)) as Arc<dyn Engine>,
                "f32 per-connection stream interpreter".to_string(),
            ),
            ("f32", "fused") => {
                let fused = FusedEngine::new(net, order).with_kernel(k).with_skip(skip);
                let st = fused.program().stats().clone();
                let summary = format!(
                    "fused schedule: {} conns -> {} macro-ops ({:.1} ops/macro-op, \
                     mean fused run {:.1}, max {}), activation skip {skip_tag}",
                    st.n_ops,
                    st.n_macro_ops(),
                    st.ops_per_macro_op(),
                    st.mean_run_len(),
                    st.max_run_len
                );
                fusion = Some(st);
                skips = Some(fused.skip_counters().clone());
                (Arc::new(fused) as Arc<dyn Engine>, summary)
            }
            ("f32", "tiled") => {
                let (engine, autotune) = if fast_mem == 0 {
                    let (program, report) =
                        TiledProgram::autotune(net, order).map_err(compile_err)?;
                    (TiledEngine::from_program(program), Some(report))
                } else {
                    (TiledEngine::new(net, order, fast_mem).map_err(compile_err)?, None)
                };
                let engine = engine.with_kernel(k).with_skip(skip);
                let st = engine.program().stats().clone();
                let tuned = match &autotune {
                    Some(r) => format!(" (autotuned, predicted {} I/Os)", r.chosen_predicted()),
                    None => String::new(),
                };
                let summary = format!(
                    "tiled schedule: M={}{tuned} -> {} segments (mean live {:.1}, max {}), \
                     {:.2} fills + {:.2} spills per conn, activation skip {skip_tag}",
                    st.m,
                    st.n_segments,
                    st.mean_live(),
                    st.max_live,
                    st.fills_per_conn(),
                    st.spills_per_conn()
                );
                tiled_stats = Some(st);
                skips = Some(engine.skip_counters().clone());
                (Arc::new(engine) as Arc<dyn Engine>, summary)
            }
            ("i8", "interp") => {
                let quant = QuantStreamEngine::new(net, order);
                let p = quant.program();
                let summary = format!(
                    "quantized stream: {:.2} B/conn vs {:.0} B/conn f32 ({:.1}x smaller), \
                     worst-case weight error {:.2e}",
                    p.bytes_per_conn(),
                    QuantStreamProgram::f32_bytes_per_conn(),
                    p.compression_ratio(),
                    p.max_weight_error()
                );
                (Arc::new(quant) as Arc<dyn Engine>, summary)
            }
            ("i8", "fused") => {
                let engine = QuantFusedEngine::new(net, order).with_kernel(k).with_skip(skip);
                let st = engine.program().stats().clone();
                let summary = format!(
                    "quant-fused schedule: {} conns -> {} macro-ops ({:.1} ops/macro-op), \
                     {:.2} B/conn i8 stream, activation skip {skip_tag}",
                    st.n_ops,
                    st.n_macro_ops(),
                    st.ops_per_macro_op(),
                    engine.program().bytes_per_conn()
                );
                fusion = Some(st);
                skips = Some(engine.skip_counters().clone());
                (Arc::new(engine) as Arc<dyn Engine>, summary)
            }
            ("i8", "tiled") => {
                let (engine, autotune) = if fast_mem == 0 {
                    let (engine, report) =
                        QuantTiledEngine::autotuned(net, order).map_err(compile_err)?;
                    (engine, Some(report))
                } else {
                    (QuantTiledEngine::new(net, order, fast_mem).map_err(compile_err)?, None)
                };
                let engine = engine.with_kernel(k).with_skip(skip);
                let st = engine.program().stats().clone();
                let tuned = match &autotune {
                    Some(r) => format!(" (autotuned, predicted {} I/Os)", r.chosen_predicted()),
                    None => String::new(),
                };
                let summary = format!(
                    "quant-tiled schedule: M={}{tuned} -> {} segments (mean live {:.1}, \
                     max {}), {:.2} B/conn i8 weights, activation skip {skip_tag}",
                    st.m,
                    st.n_segments,
                    st.mean_live(),
                    st.max_live,
                    engine.program().bytes_per_conn()
                );
                tiled_stats = Some(st);
                skips = Some(engine.skip_counters().clone());
                (Arc::new(engine) as Arc<dyn Engine>, summary)
            }
            ("f32" | "i8", other) => {
                return Err(VariantError::UnknownSchedule(other.to_string()))
            }
            (other, _) => return Err(VariantError::UnknownPrecision(other.to_string())),
        };
        let prec_tag: &'static str = if precision == "i8" { "i8" } else { "f32" };
        let sched_tag: &'static str = match schedule {
            "fused" => "fused",
            "tiled" => "tiled",
            _ => "interp",
        };
        let mut variant = if workers > 1 {
            ModelVariant::sharded(name, engine, workers)
        } else {
            ModelVariant::new(name, engine)
        };
        variant.precision = prec_tag;
        variant = variant.with_schedule(sched_tag).with_kernel_tag(kernel_tag);
        if let Some(st) = fusion {
            variant = variant.with_fusion_stats(st);
        }
        if let Some(st) = tiled_stats {
            variant = variant.with_tiled_stats(st);
        }
        if let Some(c) = skips {
            variant = variant.with_skip_counters(c);
        }
        if prec_tag == "i8" {
            // Every i8 engine (interp, fused, tiled) is bit-identical to
            // the quant interpreter over the same compressed stream, so
            // one deploy-time certificate covers the whole i8 column.
            variant.error_cert = Some(QuantStreamProgram::compress(net, order).certificate());
        }
        variant.summary = summary;
        Ok(variant)
    }

    /// A variant serving a compressed quantized stream engine
    /// (`exec::quant::QuantStreamEngine`), tagged with precision "i8".
    #[deprecated(
        since = "0.6.0",
        note = "use ModelVariant::build (or new().with_precision(\"i8\") for custom engines)"
    )]
    pub fn quantized(name: &str, engine: Arc<dyn Engine>) -> ModelVariant {
        ModelVariant::new(name, engine).with_precision("i8")
    }

    /// A variant serving a run-length block-compiled stream engine
    /// (`exec::fused::FusedEngine`), tagged with schedule "fused" and
    /// carrying its fusion statistics for the serving metrics.
    #[deprecated(
        since = "0.6.0",
        note = "use ModelVariant::build (or new().with_schedule(\"fused\") for custom engines)"
    )]
    pub fn fused(name: &str, engine: Arc<dyn Engine>, stats: FusionStats) -> ModelVariant {
        ModelVariant::new(name, engine)
            .with_schedule("fused")
            .with_fusion_stats(stats)
    }

    /// Tag the variant's op-stream schedule (composes with [`sharded`]
    /// and is orthogonal to [`with_precision`]).
    ///
    /// [`sharded`]: ModelVariant::sharded
    /// [`with_precision`]: ModelVariant::with_precision
    pub fn with_schedule(mut self, schedule: &'static str) -> ModelVariant {
        self.schedule = schedule;
        self
    }

    /// Attach fusion statistics (linked into `Metrics::snapshot` by the
    /// server under `fusion.<model>`).
    pub fn with_fusion_stats(mut self, stats: FusionStats) -> ModelVariant {
        self.fusion = Some(stats);
        self
    }

    /// Attach tiling statistics (linked into `Metrics::snapshot` by the
    /// server under `tiled.<model>`).
    pub fn with_tiled_stats(mut self, stats: TiledStats) -> ModelVariant {
        self.tiled = Some(stats);
        self
    }

    /// Attach the serving engine's activation-skip counters (linked
    /// into `Metrics::snapshot` by the server).
    pub fn with_skip_counters(mut self, counters: Arc<SkipCounters>) -> ModelVariant {
        self.skips = Some(counters);
        self
    }

    /// Tag the variant's numeric precision (composes with [`sharded`]:
    /// an i8 engine can also be batch-sharded).
    ///
    /// [`sharded`]: ModelVariant::sharded
    pub fn with_precision(mut self, precision: &'static str) -> ModelVariant {
        self.precision = precision;
        self
    }

    /// Tag the microkernel path the serving engine dispatches to
    /// ("scalar" or "avx2"; see `exec::simd`). [`ModelVariant::build`]
    /// sets it from the resolved `--kernel` knob; hand-assembled
    /// variants default to "scalar".
    pub fn with_kernel_tag(mut self, kernel: &'static str) -> ModelVariant {
        self.kernel = kernel;
        self
    }

    /// Attach the deploy-time certified accuracy bound of a quantized
    /// serving engine ([`ModelVariant::build`] sets it for every i8
    /// point; artifact-backed loaders attach it from the stored quant
    /// program).
    pub fn with_error_cert(mut self, cert: ErrorCertificate) -> ModelVariant {
        self.error_cert = Some(cert);
        self
    }

    /// A variant serving `inner` through a batch-sharded
    /// [`ParallelEngine`] with `workers` concurrent shards. The server
    /// automatically links the shard timings into its metrics.
    pub fn sharded(name: &str, inner: Arc<dyn Engine>, workers: usize) -> ModelVariant {
        let engine = ParallelEngine::with_name(inner, workers, "sharded");
        let timings = engine.shard_timings();
        let mut variant = ModelVariant::new(name, Arc::new(engine));
        variant.shard_timings = Some(timings);
        variant.workers = workers.max(1);
        variant
    }

    pub fn with_engine(mut self, engine: Arc<dyn Engine>) -> ModelVariant {
        self.engines.push(engine);
        self
    }

    pub fn with_policy(mut self, policy: RoutePolicy, density: f64) -> ModelVariant {
        self.policy = policy;
        self.density = density;
        self
    }

    /// Engine chosen by the policy.
    pub fn route(&self) -> &Arc<dyn Engine> {
        match self.policy {
            RoutePolicy::Fixed(i) => &self.engines[i.min(self.engines.len() - 1)],
            RoutePolicy::DensityHeuristic => {
                if self.density < 0.5 || self.engines.len() == 1 {
                    &self.engines[0]
                } else {
                    &self.engines[1]
                }
            }
        }
    }
}

/// The model registry.
#[derive(Default)]
pub struct Router {
    models: BTreeMap<String, ModelVariant>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn register(&mut self, variant: ModelVariant) {
        self.models.insert(variant.name.clone(), variant);
    }

    pub fn get(&self, model: &str) -> Option<&ModelVariant> {
        self.models.get(model)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::batch::BatchMatrix;

    struct FakeEngine(&'static str);
    impl Engine for FakeEngine {
        fn infer(&self, x: &BatchMatrix) -> BatchMatrix {
            x.clone()
        }
        fn name(&self) -> &'static str {
            self.0
        }
        fn n_inputs(&self) -> usize {
            1
        }
        fn n_outputs(&self) -> usize {
            1
        }
    }

    #[test]
    fn fixed_routing() {
        let v = ModelVariant::new("m", Arc::new(FakeEngine("a")))
            .with_engine(Arc::new(FakeEngine("b")))
            .with_policy(RoutePolicy::Fixed(1), 0.0);
        assert_eq!(v.route().name(), "b");
    }

    #[test]
    fn density_heuristic_prefers_stream_when_sparse() {
        let sparse = ModelVariant::new("s", Arc::new(FakeEngine("stream")))
            .with_engine(Arc::new(FakeEngine("csr")))
            .with_policy(RoutePolicy::DensityHeuristic, 0.1);
        assert_eq!(sparse.route().name(), "stream");
        let dense = ModelVariant::new("d", Arc::new(FakeEngine("stream")))
            .with_engine(Arc::new(FakeEngine("csr")))
            .with_policy(RoutePolicy::DensityHeuristic, 0.9);
        assert_eq!(dense.route().name(), "csr");
    }

    #[test]
    fn sharded_variant_routes_and_exposes_timings() {
        let v = ModelVariant::sharded("p", Arc::new(FakeEngine("inner")), 4);
        assert_eq!(v.route().name(), "sharded");
        assert!(v.shard_timings.is_some());
        // The engine serves through the adapter and matches the inner
        // engine's shape contract.
        assert_eq!(v.route().n_inputs(), 1);
        let y = v.route().infer(&BatchMatrix::from_fn(1, 8, |_, c| c as f32));
        assert_eq!(y.batch(), 8);
        assert_eq!(v.shard_timings.as_ref().unwrap().batches(), 1);
    }

    #[test]
    fn precision_tagging() {
        let v = ModelVariant::new("f", Arc::new(FakeEngine("stream")));
        assert_eq!(v.precision, "f32");
        let q = ModelVariant::new("q", Arc::new(FakeEngine("quant-stream"))).with_precision("i8");
        assert_eq!(q.precision, "i8");
        assert_eq!(q.route().name(), "quant-stream");
        // Precision composes with batch sharding.
        let sq = ModelVariant::sharded("sq", Arc::new(FakeEngine("quant-stream")), 2)
            .with_precision("i8");
        assert_eq!(sq.precision, "i8");
        assert!(sq.shard_timings.is_some());
    }

    #[test]
    fn schedule_tagging_composes() {
        let v = ModelVariant::new("i", Arc::new(FakeEngine("stream")));
        assert_eq!(v.schedule, "interp");
        assert!(v.fusion.is_none());

        let stats = FusionStats {
            n_ops: 10,
            n_dot_runs: 2,
            fused_ops: 8,
            n_singletons: 2,
            max_run_len: 5,
            ..FusionStats::default()
        };
        let f = ModelVariant::new("f", Arc::new(FakeEngine("fused-stream")))
            .with_schedule("fused")
            .with_fusion_stats(stats.clone());
        assert_eq!(f.schedule, "fused");
        assert_eq!(f.precision, "f32");
        assert_eq!(f.route().name(), "fused-stream");
        assert_eq!(f.fusion.as_ref().unwrap(), &stats);

        // Schedule composes with batch sharding.
        let sf = ModelVariant::sharded("sf", Arc::new(FakeEngine("fused-stream")), 2)
            .with_schedule("fused")
            .with_fusion_stats(stats);
        assert_eq!(sf.schedule, "fused");
        assert!(sf.shard_timings.is_some() && sf.fusion.is_some());
    }

    #[test]
    fn labels_encode_composition_point() {
        let v = ModelVariant::new("m", Arc::new(FakeEngine("stream")));
        assert_eq!(v.label(), "interp-f32-w1-scalar");
        let q = ModelVariant::new("q", Arc::new(FakeEngine("quant-stream"))).with_precision("i8");
        assert_eq!(q.label(), "interp-i8-w1-scalar");
        let sf = ModelVariant::sharded("sf", Arc::new(FakeEngine("fused-stream")), 4)
            .with_schedule("fused");
        assert_eq!(sf.label(), "fused-f32-w4-scalar");
        let kf = ModelVariant::sharded("kf", Arc::new(FakeEngine("fused-stream")), 4)
            .with_schedule("fused")
            .with_kernel_tag("avx2");
        assert_eq!(kf.label(), "fused-f32-w4-avx2");
    }

    #[test]
    fn kernel_knob_resolution() {
        // auto: compiled schedules get the best supported kernel,
        // interp is honestly tagged scalar (its per-connection loop has
        // no microkernel layer).
        let best = Kernel::auto().name();
        assert_eq!(resolve_kernel_tag("fused", "auto"), Ok(best));
        assert_eq!(resolve_kernel_tag("tiled", "auto"), Ok(best));
        assert_eq!(resolve_kernel_tag("interp", "auto"), Ok("scalar"));
        // scalar is always accepted.
        for schedule in ["interp", "fused", "tiled"] {
            assert_eq!(resolve_kernel_tag(schedule, "scalar"), Ok("scalar"));
        }
        // Explicit avx2 requires a compiled schedule...
        assert!(matches!(
            resolve_kernel_tag("interp", "avx2"),
            Err(VariantError::KernelRequiresCompiled { .. })
        ));
        // ...and runtime CPU support (exact outcome depends on the host).
        match resolve_kernel_tag("fused", "avx2") {
            Ok("avx2") => assert!(simd::avx2_supported()),
            Err(VariantError::KernelUnsupported { kernel }) => {
                assert!(!simd::avx2_supported());
                assert_eq!(kernel, "avx2");
            }
            other => panic!("unexpected resolution: {other:?}"),
        }
        assert!(matches!(
            resolve_kernel_tag("fused", "sse9"),
            Err(VariantError::UnknownKernel(k)) if k == "sse9"
        ));
    }

    /// The deprecated constructors stay as thin shims until external
    /// callers migrate to `ModelVariant::build`.
    #[test]
    #[allow(deprecated)]
    fn deprecated_constructor_shims_still_work() {
        let q = ModelVariant::quantized("q", Arc::new(FakeEngine("quant-stream")));
        assert_eq!((q.precision, q.schedule), ("i8", "interp"));
        let f = ModelVariant::fused(
            "f",
            Arc::new(FakeEngine("fused-stream")),
            FusionStats::default(),
        );
        assert_eq!((f.precision, f.schedule), ("f32", "fused"));
        assert!(f.fusion.is_some());
    }

    #[test]
    fn build_covers_the_composition_matrix() {
        use crate::ffnn::generate::{random_mlp, MlpSpec};
        use crate::ffnn::topo::two_optimal_order;
        use crate::util::rng::Pcg64;

        let mut rng = Pcg64::seed_from(0xB11D);
        let net = random_mlp(&MlpSpec::new(2, 10, 0.4), &mut rng);
        let order = two_optimal_order(&net);

        let v = ModelVariant::build("m", &net, &order, "interp", "f32", 1, 0, "auto").unwrap();
        assert_eq!(
            (v.label().as_str(), v.route().name()),
            ("interp-f32-w1-scalar", "stream")
        );
        assert!(!v.summary.is_empty());

        let v = ModelVariant::build("m", &net, &order, "fused", "f32", 1, 0, "scalar").unwrap();
        assert_eq!(v.route().name(), "fused-stream");
        assert_eq!(v.kernel, "scalar");
        assert!(v.fusion.is_some(), "fused build carries stats");
        assert!(v.skips.is_some(), "compiled builds carry skip counters");

        let v = ModelVariant::build("m", &net, &order, "interp", "i8", 1, 0, "auto").unwrap();
        assert_eq!((v.label().as_str(), v.precision), ("interp-i8-w1-scalar", "i8"));
        // Every i8 build carries the deploy-time accuracy certificate;
        // exact f32 builds do not.
        let cert = v.error_cert.expect("i8 build carries an error certificate");
        assert!(cert.slope >= 0.0 && cert.intercept >= 0.0);
        let f = ModelVariant::build("m", &net, &order, "interp", "f32", 1, 0, "auto").unwrap();
        assert!(f.error_cert.is_none());

        let v = ModelVariant::build("m", &net, &order, "fused", "f32", 3, 0, "scalar").unwrap();
        assert_eq!(v.label(), "fused-f32-w3-scalar");
        assert_eq!(v.route().name(), "sharded");
        assert!(v.shard_timings.is_some() && v.fusion.is_some());

        // The kernel knob: auto resolves to the best supported path on
        // the compiled schedules and the label records it; an explicit
        // avx2 only ever builds on a CPU that has it.
        let v = ModelVariant::build("m", &net, &order, "fused", "f32", 1, 0, "auto").unwrap();
        assert_eq!(v.kernel, Kernel::auto().name());
        assert_eq!(v.label(), format!("fused-f32-w1-{}", v.kernel));
        match ModelVariant::build("m", &net, &order, "fused", "f32", 1, 0, "avx2") {
            Ok(v) => {
                assert!(simd::avx2_supported());
                assert_eq!((v.kernel, v.label().as_str()), ("avx2", "fused-f32-w1-avx2"));
            }
            Err(VariantError::KernelUnsupported { .. }) => assert!(!simd::avx2_supported()),
            Err(e) => panic!("unexpected rejection: {e}"),
        }

        // The tiled schedule, with an explicit budget and autotuned.
        let v = ModelVariant::build("m", &net, &order, "tiled", "f32", 1, 6, "scalar").unwrap();
        assert_eq!(
            (v.label().as_str(), v.route().name()),
            ("tiled-f32-w1-scalar", "tiled-stream")
        );
        assert_eq!(v.tiled.as_ref().unwrap().m, 6);
        assert!(v.summary.contains("segments"), "{}", v.summary);
        let v = ModelVariant::build("m", &net, &order, "tiled", "f32", 2, 0, "auto").unwrap();
        assert_eq!(v.label(), format!("tiled-f32-w2-{}", Kernel::auto().name()));
        assert!(v.summary.contains("autotuned"), "{}", v.summary);
        assert!(v.shard_timings.is_some() && v.tiled.is_some());

        // The sharded + i8 composition keeps its precision tag.
        let v = ModelVariant::build("m", &net, &order, "interp", "i8", 2, 0, "auto").unwrap();
        assert_eq!((v.precision, v.workers), ("i8", 2));

        // The compiled quant engines: i8 × fused/tiled builds, carries
        // stats + skip counters, and labels the composition point.
        let v = ModelVariant::build("m", &net, &order, "fused", "i8", 1, 0, "scalar").unwrap();
        assert_eq!(
            (v.label().as_str(), v.route().name()),
            ("fused-i8-w1-scalar", "quant-fused-stream")
        );
        assert!(v.fusion.is_some() && v.skips.is_some());
        assert!(v.summary.contains("B/conn"), "{}", v.summary);

        let v = ModelVariant::build("m", &net, &order, "tiled", "i8", 2, 6, "scalar").unwrap();
        assert_eq!(
            (v.label().as_str(), v.route().name()),
            ("tiled-i8-w2-scalar", "sharded")
        );
        assert_eq!(v.tiled.as_ref().unwrap().m, 6);
        assert!(v.shard_timings.is_some() && v.skips.is_some());

        let v = ModelVariant::build("m", &net, &order, "tiled", "i8", 1, 0, "auto").unwrap();
        assert!(v.summary.contains("autotuned"), "{}", v.summary);
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "tiled", "i8", 1, 2, "auto"),
            Err(VariantError::Compile { .. })
        ));

        // Skipping is an engine option, not a different composition
        // point: off still builds the same variant, flag recorded in
        // the summary.
        let v =
            ModelVariant::build_with_opts("m", &net, &order, "fused", "i8", 1, 0, "auto", false)
                .unwrap();
        assert!(v.summary.contains("skip off"), "{}", v.summary);
        assert!(v.skips.is_some());

        // Invalid points are rejected with structured errors, not
        // silently coerced (and not stringly typed).
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "jit", "f32", 1, 0, "auto"),
            Err(VariantError::UnknownSchedule(s)) if s == "jit"
        ));
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "interp", "f16", 1, 0, "auto"),
            Err(VariantError::UnknownPrecision(p)) if p == "f16"
        ));
        // --fast-mem is tiled-only, and a sub-minimum budget fails in
        // the tiled compiler.
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "interp", "f32", 1, 64, "auto"),
            Err(VariantError::FastMemRequiresTiled { fast_mem: 64, .. })
        ));
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "tiled", "f32", 1, 2, "auto"),
            Err(VariantError::Compile { .. })
        ));
        // The kernel knob's own rejections.
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "interp", "f32", 1, 0, "avx2"),
            Err(VariantError::KernelRequiresCompiled { .. })
        ));
        assert!(matches!(
            ModelVariant::build("m", &net, &order, "fused", "f32", 1, 0, "sse9"),
            Err(VariantError::UnknownKernel(k)) if k == "sse9"
        ));
    }

    #[test]
    fn registry_lookup() {
        let mut r = Router::new();
        r.register(ModelVariant::new("alpha", Arc::new(FakeEngine("a"))));
        r.register(ModelVariant::new("beta", Arc::new(FakeEngine("b"))));
        assert!(r.get("alpha").is_some());
        assert!(r.get("gamma").is_none());
        assert_eq!(r.model_names(), vec!["alpha", "beta"]);
    }
}
