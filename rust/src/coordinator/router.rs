//! Model registry + engine routing.
//!
//! A [`ModelVariant`] owns one or more engines for the same network (e.g.
//! the reordered streaming engine, the CSR layer-wise baseline, and the
//! XLA artifact). The router picks the serving engine per the variant's
//! policy; the benches use explicit engine selection to compare them.

use crate::exec::fused::FusionStats;
use crate::exec::parallel::{ParallelEngine, ShardTimings};
use crate::exec::Engine;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Engine-selection policy for a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always use the engine registered under this index.
    Fixed(usize),
    /// Use the density heuristic of the paper's Fig. 7: streaming wins
    /// for sparse networks, layer-wise CSR for dense ones. The variant
    /// stores the network density; below `0.5` → engine 0 (stream),
    /// else engine 1 (csr) if present.
    DensityHeuristic,
}

/// A registered model with its candidate engines.
pub struct ModelVariant {
    pub name: String,
    pub engines: Vec<Arc<dyn Engine>>,
    pub policy: RoutePolicy,
    /// Edge density of the underlying network (for the heuristic).
    pub density: f64,
    /// Per-shard timing counters when the serving engine is a
    /// [`ParallelEngine`]; the server links these into its metrics.
    pub shard_timings: Option<Arc<ShardTimings>>,
    /// Numeric precision of the serving engine: "f32" (default) or
    /// "i8" (compressed quantized stream). Orthogonal to sharding.
    pub precision: &'static str,
    /// Op-stream schedule of the serving engine: "interp" (default, the
    /// per-connection stream interpreter) or "fused" (the run-length
    /// block-compiled engine). Orthogonal to sharding; f32-only (see the
    /// composition matrix in `exec`'s module docs).
    pub schedule: &'static str,
    /// Compile-time fusion statistics when the serving engine is a
    /// `FusedEngine`; the server surfaces these in `Metrics::snapshot`
    /// under `fusion.<model>`.
    pub fusion: Option<FusionStats>,
}

impl ModelVariant {
    pub fn new(name: &str, engine: Arc<dyn Engine>) -> ModelVariant {
        ModelVariant {
            name: name.to_string(),
            engines: vec![engine],
            policy: RoutePolicy::Fixed(0),
            density: 0.0,
            shard_timings: None,
            precision: "f32",
            schedule: "interp",
            fusion: None,
        }
    }

    /// A variant serving a compressed quantized stream engine
    /// (`exec::quant::QuantStreamEngine`), tagged with precision "i8".
    pub fn quantized(name: &str, engine: Arc<dyn Engine>) -> ModelVariant {
        ModelVariant::new(name, engine).with_precision("i8")
    }

    /// A variant serving a run-length block-compiled stream engine
    /// (`exec::fused::FusedEngine`), tagged with schedule "fused" and
    /// carrying its fusion statistics for the serving metrics.
    pub fn fused(name: &str, engine: Arc<dyn Engine>, stats: FusionStats) -> ModelVariant {
        ModelVariant::new(name, engine)
            .with_schedule("fused")
            .with_fusion_stats(stats)
    }

    /// Tag the variant's op-stream schedule (composes with [`sharded`]
    /// and is orthogonal to [`with_precision`]).
    ///
    /// [`sharded`]: ModelVariant::sharded
    /// [`with_precision`]: ModelVariant::with_precision
    pub fn with_schedule(mut self, schedule: &'static str) -> ModelVariant {
        self.schedule = schedule;
        self
    }

    /// Attach fusion statistics (linked into `Metrics::snapshot` by the
    /// server under `fusion.<model>`).
    pub fn with_fusion_stats(mut self, stats: FusionStats) -> ModelVariant {
        self.fusion = Some(stats);
        self
    }

    /// Tag the variant's numeric precision (composes with [`sharded`]:
    /// an i8 engine can also be batch-sharded).
    ///
    /// [`sharded`]: ModelVariant::sharded
    pub fn with_precision(mut self, precision: &'static str) -> ModelVariant {
        self.precision = precision;
        self
    }

    /// A variant serving `inner` through a batch-sharded
    /// [`ParallelEngine`] with `workers` concurrent shards. The server
    /// automatically links the shard timings into its metrics.
    pub fn sharded(name: &str, inner: Arc<dyn Engine>, workers: usize) -> ModelVariant {
        let engine = ParallelEngine::with_name(inner, workers, "sharded");
        let timings = engine.shard_timings();
        let mut variant = ModelVariant::new(name, Arc::new(engine));
        variant.shard_timings = Some(timings);
        variant
    }

    pub fn with_engine(mut self, engine: Arc<dyn Engine>) -> ModelVariant {
        self.engines.push(engine);
        self
    }

    pub fn with_policy(mut self, policy: RoutePolicy, density: f64) -> ModelVariant {
        self.policy = policy;
        self.density = density;
        self
    }

    /// Engine chosen by the policy.
    pub fn route(&self) -> &Arc<dyn Engine> {
        match self.policy {
            RoutePolicy::Fixed(i) => &self.engines[i.min(self.engines.len() - 1)],
            RoutePolicy::DensityHeuristic => {
                if self.density < 0.5 || self.engines.len() == 1 {
                    &self.engines[0]
                } else {
                    &self.engines[1]
                }
            }
        }
    }
}

/// The model registry.
#[derive(Default)]
pub struct Router {
    models: BTreeMap<String, ModelVariant>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn register(&mut self, variant: ModelVariant) {
        self.models.insert(variant.name.clone(), variant);
    }

    pub fn get(&self, model: &str) -> Option<&ModelVariant> {
        self.models.get(model)
    }

    pub fn model_names(&self) -> Vec<&str> {
        self.models.keys().map(String::as_str).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::batch::BatchMatrix;

    struct FakeEngine(&'static str);
    impl Engine for FakeEngine {
        fn infer(&self, x: &BatchMatrix) -> BatchMatrix {
            x.clone()
        }
        fn name(&self) -> &'static str {
            self.0
        }
        fn n_inputs(&self) -> usize {
            1
        }
        fn n_outputs(&self) -> usize {
            1
        }
    }

    #[test]
    fn fixed_routing() {
        let v = ModelVariant::new("m", Arc::new(FakeEngine("a")))
            .with_engine(Arc::new(FakeEngine("b")))
            .with_policy(RoutePolicy::Fixed(1), 0.0);
        assert_eq!(v.route().name(), "b");
    }

    #[test]
    fn density_heuristic_prefers_stream_when_sparse() {
        let sparse = ModelVariant::new("s", Arc::new(FakeEngine("stream")))
            .with_engine(Arc::new(FakeEngine("csr")))
            .with_policy(RoutePolicy::DensityHeuristic, 0.1);
        assert_eq!(sparse.route().name(), "stream");
        let dense = ModelVariant::new("d", Arc::new(FakeEngine("stream")))
            .with_engine(Arc::new(FakeEngine("csr")))
            .with_policy(RoutePolicy::DensityHeuristic, 0.9);
        assert_eq!(dense.route().name(), "csr");
    }

    #[test]
    fn sharded_variant_routes_and_exposes_timings() {
        let v = ModelVariant::sharded("p", Arc::new(FakeEngine("inner")), 4);
        assert_eq!(v.route().name(), "sharded");
        assert!(v.shard_timings.is_some());
        // The engine serves through the adapter and matches the inner
        // engine's shape contract.
        assert_eq!(v.route().n_inputs(), 1);
        let y = v.route().infer(&BatchMatrix::from_fn(1, 8, |_, c| c as f32));
        assert_eq!(y.batch(), 8);
        assert_eq!(v.shard_timings.as_ref().unwrap().batches(), 1);
    }

    #[test]
    fn precision_tagging() {
        let v = ModelVariant::new("f", Arc::new(FakeEngine("stream")));
        assert_eq!(v.precision, "f32");
        let q = ModelVariant::quantized("q", Arc::new(FakeEngine("quant-stream")));
        assert_eq!(q.precision, "i8");
        assert_eq!(q.route().name(), "quant-stream");
        // Precision composes with batch sharding.
        let sq = ModelVariant::sharded("sq", Arc::new(FakeEngine("quant-stream")), 2)
            .with_precision("i8");
        assert_eq!(sq.precision, "i8");
        assert!(sq.shard_timings.is_some());
    }

    #[test]
    fn schedule_tagging_composes() {
        let v = ModelVariant::new("i", Arc::new(FakeEngine("stream")));
        assert_eq!(v.schedule, "interp");
        assert!(v.fusion.is_none());

        let stats = FusionStats {
            n_ops: 10,
            n_dot_runs: 2,
            fused_ops: 8,
            n_singletons: 2,
            max_run_len: 5,
            ..FusionStats::default()
        };
        let f = ModelVariant::fused("f", Arc::new(FakeEngine("fused-stream")), stats.clone());
        assert_eq!(f.schedule, "fused");
        assert_eq!(f.precision, "f32");
        assert_eq!(f.route().name(), "fused-stream");
        assert_eq!(f.fusion.as_ref().unwrap(), &stats);

        // Schedule composes with batch sharding.
        let sf = ModelVariant::sharded("sf", Arc::new(FakeEngine("fused-stream")), 2)
            .with_schedule("fused")
            .with_fusion_stats(stats);
        assert_eq!(sf.schedule, "fused");
        assert!(sf.shard_timings.is_some() && sf.fusion.is_some());
    }

    #[test]
    fn registry_lookup() {
        let mut r = Router::new();
        r.register(ModelVariant::new("alpha", Arc::new(FakeEngine("a"))));
        r.register(ModelVariant::new("beta", Arc::new(FakeEngine("b"))));
        assert!(r.get("alpha").is_some());
        assert!(r.get("gamma").is_none());
        assert_eq!(r.model_names(), vec!["alpha", "beta"]);
    }
}
