//! Dynamic batching: group single requests into batches of up to
//! `max_batch`, waiting at most `max_wait` after the first request of a
//! batch arrives. This is the standard production trade-off (latency vs
//! SIMD/bandwidth utilization) the paper's batch-128 experiments assume.

use super::request::Request;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Message on a model's request queue. The explicit `Shutdown` sentinel
/// lets the server stop its dispatchers even while client handles (which
/// hold sender clones) are still alive.
pub enum QueueMsg {
    Req(Request),
    Shutdown,
}

/// Collect the next batch from `rx`.
///
/// Blocks until at least one request arrives, then keeps pulling until
/// the batch is full or `max_wait` has elapsed since the first request.
/// Returns `(batch, stop)`; `stop` is true when the dispatcher should
/// exit after processing the batch (shutdown sentinel or channel closed).
pub fn next_batch(rx: &mpsc::Receiver<QueueMsg>, policy: &BatchPolicy) -> (Vec<Request>, bool) {
    let mut batch = Vec::with_capacity(policy.max_batch);
    match rx.recv() {
        Ok(QueueMsg::Req(first)) => batch.push(first),
        Ok(QueueMsg::Shutdown) | Err(_) => return (batch, true),
    }
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(QueueMsg::Req(req)) => batch.push(req),
            Ok(QueueMsg::Shutdown) => return (batch, true),
            Err(mpsc::RecvTimeoutError::Timeout) => break,
            Err(mpsc::RecvTimeoutError::Disconnected) => return (batch, true),
        }
    }
    (batch, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    type ReplyRx = mpsc::Receiver<Result<super::super::Response, super::super::InferenceError>>;

    fn req(id: u64) -> (QueueMsg, ReplyRx) {
        let (tx, rx) = channel();
        (
            QueueMsg::Req(Request {
                id,
                model: "m".into(),
                input: vec![0.0],
                enqueued: Instant::now(),
                reply: tx,
            }),
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..10 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let (b, stop) = next_batch(&rx, &policy);
        assert_eq!(b.len(), 4);
        assert!(!stop);
        assert_eq!(b[0].id, 0);
        let (b2, _) = next_batch(&rx, &policy);
        assert_eq!(b2.len(), 4);
        let (b3, _) = next_batch(&rx, &policy);
        assert_eq!(b3.len(), 2, "drains the remainder at timeout");
    }

    #[test]
    fn stops_when_closed() {
        let (tx, rx) = channel::<QueueMsg>();
        drop(tx);
        let (b, stop) = next_batch(&rx, &BatchPolicy::default());
        assert!(b.is_empty());
        assert!(stop);
    }

    #[test]
    fn stops_on_shutdown_sentinel() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        tx.send(QueueMsg::Shutdown).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(5) };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy);
        assert_eq!(b.len(), 1, "pending request still served");
        assert!(stop);
        assert!(start.elapsed() < Duration::from_secs(1));
        // Next call sees a closed/empty queue state and stops immediately.
        drop(tx);
        let (b2, stop2) = next_batch(&rx, &policy);
        assert!(b2.is_empty());
        assert!(stop2);
    }

    #[test]
    fn partial_batch_after_wait() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        let policy = BatchPolicy { max_batch: 128, max_wait: Duration::from_millis(5) };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy);
        assert_eq!(b.len(), 1);
        assert!(!stop);
        assert!(start.elapsed() >= Duration::from_millis(4), "must wait out max_wait");
    }

    #[test]
    fn closed_mid_batch_returns_partial() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(5) };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy);
        assert_eq!(b.len(), 1);
        assert!(stop);
        assert!(start.elapsed() < Duration::from_secs(1), "must not wait full 5s");
    }
}
