//! Dynamic batching: group single requests into batches of up to
//! `max_batch`, waiting at most `max_wait` after the first request of a
//! batch arrives. This is the standard production trade-off (latency vs
//! SIMD/bandwidth utilization) the paper's batch-128 experiments assume.
//!
//! The batcher is deadline-aware: when the first request of a batch
//! carries a deadline, the collection window is cut short so the request
//! still has `reserve_frac` of its total budget left for compute when the
//! batch closes (adaptive batch close). Admission control lives in the
//! server (`ServerHandle::submit` sheds at `max_queue`); the batcher's
//! side of the contract is decrementing the shared queue-depth counter as
//! it pops requests.

use super::request::Request;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Fraction of the *oldest* request's deadline budget (deadline −
    /// enqueue) reserved for compute: the batch closes no later than
    /// `deadline − reserve_frac · budget`, even if `max_wait` has not
    /// elapsed. Ignored for requests without a deadline. Clamped to
    /// `[0, 1]` at use time.
    pub reserve_frac: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 128,
            max_wait: Duration::from_millis(2),
            reserve_frac: 0.25,
        }
    }
}

/// Message on a model's request queue. The explicit `Shutdown` sentinel
/// lets the server stop its dispatchers even while client handles (which
/// hold sender clones) are still alive.
pub enum QueueMsg {
    Req(Request),
    Shutdown,
}

/// Latest instant at which a batch led by `first` may still be
/// collecting: `first`'s batcher-arrival time + `max_wait`, cut to
/// `deadline − reserve_frac · budget` when `first` has a deadline.
fn close_at(first: &Request, policy: &BatchPolicy) -> Instant {
    let mut at = Instant::now() + policy.max_wait;
    if let Some(d) = first.deadline {
        let budget = d.saturating_duration_since(first.enqueued);
        let reserve = budget.mul_f64(policy.reserve_frac.clamp(0.0, 1.0));
        if let Some(cut) = d.checked_sub(reserve) {
            at = at.min(cut);
        }
    }
    at
}

/// Saturating decrement of the shared queue-depth counter (never wraps:
/// unit tests feed the batcher directly without going through
/// `ServerHandle::submit`'s increment).
fn pop_depth(depth: &AtomicUsize) {
    let mut cur = depth.load(Ordering::Relaxed);
    while cur > 0 {
        match depth.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Collect the next batch from `rx`, decrementing `depth` per popped
/// request.
///
/// Blocks until at least one request arrives, then keeps pulling until
/// the batch is full or the close deadline (see [`close_at`]) has passed.
/// A final non-blocking drain then picks up everything already queued, so
/// `max_wait = 0` (or an already-expired request deadline) dispatches
/// immediately with *all* pending requests rather than a batch of one —
/// and never spins.
///
/// Returns `(batch, stop)`; `stop` is true when the dispatcher should
/// exit after processing the (possibly partial) batch — shutdown sentinel
/// or channel closed mid-fill both still deliver the requests collected
/// so far.
pub fn next_batch(
    rx: &mpsc::Receiver<QueueMsg>,
    policy: &BatchPolicy,
    depth: &AtomicUsize,
) -> (Vec<Request>, bool) {
    let mut batch = Vec::with_capacity(policy.max_batch.max(1));
    match rx.recv() {
        Ok(QueueMsg::Req(first)) => {
            pop_depth(depth);
            batch.push(first);
        }
        Ok(QueueMsg::Shutdown) | Err(_) => return (batch, true),
    }
    let mut stop = false;
    let deadline = close_at(&batch[0], policy);
    while batch.len() < policy.max_batch && !stop {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(QueueMsg::Req(req)) => {
                pop_depth(depth);
                batch.push(req);
            }
            Ok(QueueMsg::Shutdown) => stop = true,
            Err(mpsc::RecvTimeoutError::Timeout) => break,
            Err(mpsc::RecvTimeoutError::Disconnected) => stop = true,
        }
    }
    // Non-blocking drain of whatever else is already queued.
    while !stop && batch.len() < policy.max_batch {
        match rx.try_recv() {
            Ok(QueueMsg::Req(req)) => {
                pop_depth(depth);
                batch.push(req);
            }
            Ok(QueueMsg::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => stop = true,
            Err(mpsc::TryRecvError::Empty) => break,
        }
    }
    (batch, stop)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    type ReplyRx = mpsc::Receiver<Result<super::super::Response, super::super::InferenceError>>;

    fn req(id: u64) -> (QueueMsg, ReplyRx) {
        req_with_deadline(id, None)
    }

    fn req_with_deadline(id: u64, deadline: Option<Duration>) -> (QueueMsg, ReplyRx) {
        let (tx, rx) = channel();
        let now = Instant::now();
        (
            QueueMsg::Req(Request {
                id,
                model: "m".into(),
                input: vec![0.0],
                enqueued: now,
                deadline: deadline.map(|d| now + d),
                reply: tx,
            }),
            rx,
        )
    }

    fn depth() -> AtomicUsize {
        AtomicUsize::new(0)
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..10 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let d = depth();
        let (b, stop) = next_batch(&rx, &policy, &d);
        assert_eq!(b.len(), 4);
        assert!(!stop);
        assert_eq!(b[0].id, 0);
        let (b2, _) = next_batch(&rx, &policy, &d);
        assert_eq!(b2.len(), 4);
        let (b3, _) = next_batch(&rx, &policy, &d);
        assert_eq!(b3.len(), 2, "drains the remainder at timeout");
    }

    #[test]
    fn stops_when_closed() {
        let (tx, rx) = channel::<QueueMsg>();
        drop(tx);
        let (b, stop) = next_batch(&rx, &BatchPolicy::default(), &depth());
        assert!(b.is_empty());
        assert!(stop);
    }

    #[test]
    fn stops_on_shutdown_sentinel() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        tx.send(QueueMsg::Shutdown).unwrap();
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 1, "pending request still served");
        assert!(stop);
        assert!(start.elapsed() < Duration::from_secs(1));
        // Next call sees a closed/empty queue state and stops immediately.
        drop(tx);
        let (b2, stop2) = next_batch(&rx, &policy, &depth());
        assert!(b2.is_empty());
        assert!(stop2);
    }

    #[test]
    fn shutdown_mid_fill_delivers_partial_batch() {
        // Several requests already queued, then the sentinel: every
        // request collected before the sentinel must come back in the
        // batch (they get processed, not dropped), with stop = true.
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        tx.send(QueueMsg::Shutdown).unwrap();
        let policy = BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 3, "partial batch survives shutdown");
        assert!(stop);
        assert!(start.elapsed() < Duration::from_secs(1), "must not wait out max_wait");
    }

    #[test]
    fn partial_batch_after_wait() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        let policy = BatchPolicy {
            max_batch: 128,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 1);
        assert!(!stop);
        assert!(start.elapsed() >= Duration::from_millis(4), "must wait out max_wait");
    }

    #[test]
    fn zero_wait_dispatches_everything_queued_immediately() {
        // The regression this pins: max_wait = 0 used to return a batch
        // of one, leaving queued requests for the next iteration. It must
        // drain whatever is already queued — immediately, without
        // spinning or sleeping.
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 128,
            max_wait: Duration::ZERO,
            ..Default::default()
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 5, "must take all queued requests");
        assert!(!stop);
        assert!(start.elapsed() < Duration::from_millis(50), "immediate dispatch");
        // Queue is now empty: the next zero-wait call returns one request
        // as soon as it arrives.
        let (r, _keep) = req(9);
        tx.send(r).unwrap();
        let (b2, _) = next_batch(&rx, &policy, &depth());
        assert_eq!(b2.len(), 1);
    }

    #[test]
    fn zero_wait_respects_max_batch() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..6 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::ZERO,
            ..Default::default()
        };
        let d = depth();
        let (b, _) = next_batch(&rx, &policy, &d);
        assert_eq!(b.len(), 4);
        let (b2, _) = next_batch(&rx, &policy, &d);
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn deadline_cuts_collection_window() {
        // First request has a 10 ms deadline and reserve_frac 0.5, so the
        // batch must close ~5 ms after enqueue even though max_wait is
        // 5 s.
        let (tx, rx) = channel();
        let (r, _keep) = req_with_deadline(1, Some(Duration::from_millis(10)));
        tx.send(r).unwrap();
        let policy = BatchPolicy {
            max_batch: 128,
            max_wait: Duration::from_secs(5),
            reserve_frac: 0.5,
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 1);
        assert!(!stop);
        assert!(
            start.elapsed() < Duration::from_millis(500),
            "deadline budget must cut the 5 s window, waited {:?}",
            start.elapsed()
        );
    }

    #[test]
    fn expired_deadline_closes_immediately_with_drain() {
        // A first request whose deadline already passed: close time is in
        // the past, so the batch dispatches immediately — still draining
        // the rest of the queue so the server can reject them in one
        // sweep.
        let (tx, rx) = channel();
        let (r, _k0) = req_with_deadline(1, Some(Duration::ZERO));
        tx.send(r).unwrap();
        let (r2, _k1) = req(2);
        tx.send(r2).unwrap();
        let policy = BatchPolicy {
            max_batch: 128,
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        let start = Instant::now();
        let (b, _) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 2);
        assert!(start.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn closed_mid_batch_returns_partial() {
        let (tx, rx) = channel();
        let (r, _keep) = req(1);
        tx.send(r).unwrap();
        drop(tx);
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_secs(5),
            ..Default::default()
        };
        let start = Instant::now();
        let (b, stop) = next_batch(&rx, &policy, &depth());
        assert_eq!(b.len(), 1);
        assert!(stop);
        assert!(start.elapsed() < Duration::from_secs(1), "must not wait full 5s");
    }

    #[test]
    fn depth_counter_decrements_per_pop_and_saturates() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, rep) = req(i);
            keep.push(rep);
            tx.send(r).unwrap();
        }
        let d = AtomicUsize::new(2); // deliberately under-counted
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::ZERO,
            ..Default::default()
        };
        let (b, _) = next_batch(&rx, &policy, &d);
        assert_eq!(b.len(), 3);
        assert_eq!(d.load(Ordering::Relaxed), 0, "saturates at zero, never wraps");
    }
}
