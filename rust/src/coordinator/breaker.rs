//! Per-model circuit breaker: closed → open → half-open with probes.
//!
//! The dispatcher reports every engine invocation's outcome to the
//! model's [`Breaker`]; admission asks it before queueing new work.
//! After `fault_threshold` **consecutive** faults (panics contained by
//! the dispatcher, or invocations exceeding the `hang_cap` wall-clock
//! budget) the breaker *opens*: submissions are shed immediately with
//! [`InferenceError::Unhealthy`] instead of queueing doomed work. After
//! `cooldown` it admits a single *half-open probe*; a successful probe
//! closes the breaker, a faulting probe reopens it for another
//! cooldown. Successes always reset the consecutive-fault count, so
//! isolated faults in a healthy stream never trip it.
//!
//! The hang watchdog is admission-side: the dispatcher brackets each
//! engine call with [`Breaker::begin_inference`] / the `elapsed` passed
//! to [`Breaker::observe`], and [`Breaker::admit`] treats an in-flight
//! call older than `hang_cap` as a fault-in-progress — new submissions
//! shed while the engine is wedged, without needing a poller thread,
//! and the overdue call counts as a fault when (if) it returns.
//!
//! All transitions are panic-proof: the internal mutex is recovered
//! from poisoning, since the whole point of this module is surviving
//! unwinding threads.
//!
//! [`InferenceError::Unhealthy`]: super::request::InferenceError::Unhealthy

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Thresholds governing a model's circuit breaker. The default policy
/// is fully disabled (`fault_threshold` 0, no `hang_cap`): library
/// users opt in, and `sparseflow serve` enables it via the
/// `breaker_faults` / `breaker_cooldown_ms` / `hang_cap_ms` config
/// knobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BreakerPolicy {
    /// Consecutive engine faults that open the breaker. 0 = never open
    /// on faults.
    pub fault_threshold: u32,
    /// How long the breaker stays open before admitting a half-open
    /// probe request.
    pub cooldown: Duration,
    /// Hard wall-clock cap on a single engine invocation; an
    /// invocation running (or having run) longer counts as a fault.
    /// `None` = no hang detection.
    pub hang_cap: Option<Duration>,
}

impl Default for BreakerPolicy {
    fn default() -> BreakerPolicy {
        BreakerPolicy {
            fault_threshold: 0,
            cooldown: Duration::from_secs(1),
            hang_cap: None,
        }
    }
}

impl BreakerPolicy {
    /// True when any tripping condition is configured.
    pub fn enabled(&self) -> bool {
        self.fault_threshold > 0 || self.hang_cap.is_some()
    }
}

/// Breaker state machine position (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

#[derive(Debug)]
struct Inner {
    state: BreakerState,
    /// Consecutive faults since the last success.
    consecutive: u32,
    /// When the breaker last opened / last admitted a probe (drives the
    /// cooldown and the probe re-arm).
    since: Instant,
    /// Start of the engine invocation currently in flight, if any
    /// (dispatchers run one invocation at a time per model).
    inflight_since: Option<Instant>,
}

/// One model's circuit breaker (see module docs).
#[derive(Debug)]
pub struct Breaker {
    policy: BreakerPolicy,
    inner: Mutex<Inner>,
    /// Times the breaker transitioned to open (diagnostic counter).
    trips: AtomicU64,
}

impl Breaker {
    pub fn new(policy: BreakerPolicy) -> Breaker {
        Breaker {
            policy,
            inner: Mutex::new(Inner {
                state: BreakerState::Closed,
                consecutive: 0,
                since: Instant::now(),
                inflight_since: None,
            }),
            trips: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> BreakerPolicy {
        self.policy
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // Recover from poisoning: a panicking thread elsewhere must not
        // take the breaker down with it.
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Admission check: may a new request be queued for this model?
    /// Open breakers deny until `cooldown` elapses, then admit exactly
    /// one half-open probe (re-armed every further `cooldown` in case a
    /// probe is lost to shedding and never reports back).
    pub fn admit(&self) -> bool {
        if !self.policy.enabled() {
            return true;
        }
        let mut g = self.lock();
        // Hang watchdog: an in-flight invocation past the cap means the
        // dispatcher is wedged — open now so callers shed instead of
        // queueing behind it.
        if let (Some(cap), Some(started)) = (self.policy.hang_cap, g.inflight_since) {
            if started.elapsed() > cap && g.state == BreakerState::Closed {
                g.state = BreakerState::Open;
                g.since = Instant::now();
                self.trips.fetch_add(1, Ordering::Relaxed);
            }
        }
        match g.state {
            BreakerState::Closed => true,
            BreakerState::Open | BreakerState::HalfOpen => {
                if g.since.elapsed() >= self.policy.cooldown {
                    g.state = BreakerState::HalfOpen;
                    g.since = Instant::now();
                    true // this caller is the probe
                } else {
                    false
                }
            }
        }
    }

    /// Mark the start of an engine invocation (feeds the hang watchdog).
    pub fn begin_inference(&self) {
        self.lock().inflight_since = Some(Instant::now());
    }

    /// Report an invocation's outcome: `faulted` = the engine panicked;
    /// `elapsed` = wall-clock compute time (an over-cap duration counts
    /// as a fault even when the result arrived). Clears the in-flight
    /// marker and advances the state machine.
    pub fn observe(&self, faulted: bool, elapsed: Duration) {
        let hung = self.policy.hang_cap.is_some_and(|cap| elapsed > cap);
        let mut g = self.lock();
        g.inflight_since = None;
        if faulted || hung {
            g.consecutive = g.consecutive.saturating_add(1);
            let trip = match g.state {
                // A faulting half-open probe reopens immediately.
                BreakerState::HalfOpen => true,
                BreakerState::Closed => {
                    self.policy.fault_threshold > 0
                        && g.consecutive >= self.policy.fault_threshold
                }
                BreakerState::Open => false,
            };
            if trip {
                g.state = BreakerState::Open;
                g.since = Instant::now();
                self.trips.fetch_add(1, Ordering::Relaxed);
            }
        } else {
            g.consecutive = 0;
            // A successful probe (or any late success from already-queued
            // work) proves the model healthy again.
            g.state = BreakerState::Closed;
        }
    }

    pub fn state(&self) -> BreakerState {
        self.lock().state
    }

    /// Consecutive faults since the last success.
    pub fn consecutive_faults(&self) -> u32 {
        self.lock().consecutive
    }

    /// Times the breaker has opened.
    pub fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }

    /// Remaining cooldown before the next half-open probe is admitted,
    /// when the breaker is open (or waiting out a probe). `None` while
    /// closed — the overload controller uses this to derive the
    /// `retry_after_ms` hint on `Unhealthy` replies.
    pub fn retry_after(&self) -> Option<Duration> {
        let g = self.lock();
        match g.state {
            BreakerState::Closed => None,
            BreakerState::Open | BreakerState::HalfOpen => {
                Some(self.policy.cooldown.saturating_sub(g.since.elapsed()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn policy(k: u32, cooldown_ms: u64) -> BreakerPolicy {
        BreakerPolicy {
            fault_threshold: k,
            cooldown: Duration::from_millis(cooldown_ms),
            hang_cap: None,
        }
    }

    #[test]
    fn disabled_breaker_admits_through_faults() {
        let b = Breaker::new(BreakerPolicy::default());
        for _ in 0..100 {
            b.observe(true, Duration::ZERO);
            assert!(b.admit());
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 0);
    }

    #[test]
    fn opens_after_k_consecutive_faults_and_probes_after_cooldown() {
        let b = Breaker::new(policy(3, 20));
        b.observe(true, Duration::ZERO);
        b.observe(true, Duration::ZERO);
        assert!(b.admit(), "below threshold stays closed");
        b.observe(true, Duration::ZERO);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(), "open breaker sheds");
        std::thread::sleep(Duration::from_millis(30));
        assert!(b.admit(), "cooldown elapsed: one probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit(), "only one probe per cooldown");
        b.observe(false, Duration::ZERO);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit());
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn faulting_probe_reopens() {
        let b = Breaker::new(policy(1, 10));
        b.observe(true, Duration::ZERO);
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.admit());
        b.observe(true, Duration::ZERO);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 2);
        assert!(!b.admit(), "freshly reopened: cooldown restarts");
    }

    #[test]
    fn success_resets_consecutive_count() {
        let b = Breaker::new(policy(3, 10));
        for _ in 0..10 {
            b.observe(true, Duration::ZERO);
            b.observe(true, Duration::ZERO);
            b.observe(false, Duration::ZERO);
        }
        assert_eq!(b.state(), BreakerState::Closed, "never 3 in a row");
        assert_eq!(b.consecutive_faults(), 0);
    }

    #[test]
    fn over_cap_elapsed_counts_as_fault() {
        let b = Breaker::new(BreakerPolicy {
            fault_threshold: 1,
            cooldown: Duration::from_millis(10),
            hang_cap: Some(Duration::from_millis(5)),
        });
        b.observe(false, Duration::from_millis(50));
        assert_eq!(b.state(), BreakerState::Open, "slow success still trips");
    }

    #[test]
    fn inflight_past_cap_sheds_at_admission() {
        let b = Breaker::new(BreakerPolicy {
            fault_threshold: 0,
            cooldown: Duration::from_millis(50),
            hang_cap: Some(Duration::from_millis(5)),
        });
        b.begin_inference();
        assert!(b.admit(), "fresh in-flight call: still healthy");
        std::thread::sleep(Duration::from_millis(15));
        assert!(!b.admit(), "wedged inference opens the breaker");
        assert_eq!(b.state(), BreakerState::Open);
        // The overdue call finally returns: counted as a fault, and the
        // breaker stays open until cooldown.
        b.observe(false, Duration::from_millis(15));
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn lost_probe_rearms_after_another_cooldown() {
        let b = Breaker::new(policy(1, 10));
        b.observe(true, Duration::ZERO);
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.admit(), "first probe admitted");
        // Probe never reports back (e.g. shed later in the pipeline).
        std::thread::sleep(Duration::from_millis(15));
        assert!(b.admit(), "probe re-armed instead of wedging half-open");
    }

    #[test]
    fn retry_after_tracks_cooldown_remainder() {
        let b = Breaker::new(policy(1, 50));
        assert_eq!(b.retry_after(), None, "closed breaker has no retry hint");
        b.observe(true, Duration::ZERO);
        let r = b.retry_after().expect("open breaker exposes its cooldown remainder");
        assert!(r <= Duration::from_millis(50));
        b.observe(false, Duration::ZERO);
        assert_eq!(b.retry_after(), None, "success closes and clears the hint");
    }

    #[test]
    fn state_names() {
        assert_eq!(BreakerState::Closed.name(), "closed");
        assert_eq!(BreakerState::Open.name(), "open");
        assert_eq!(BreakerState::HalfOpen.name(), "half-open");
    }
}
