//! Serving metrics: request counters, batch-size histogram, a
//! log-bucketed latency histogram with quantile estimation, linked
//! per-shard timing sinks from batch-sharded engines, and per-model
//! fusion statistics from block-compiled engines. Lock-free on the hot
//! path (atomics only; the sink lists are only locked at link and
//! snapshot time); snapshots serialize to JSON.

use crate::exec::fused::FusionStats;
use crate::exec::parallel::ShardTimings;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Latency histogram: log-spaced buckets from 1 µs to ~17 s.
const N_BUCKETS: usize = 48;

pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    latency_buckets: [AtomicU64; N_BUCKETS],
    /// Per-model shard-timing sinks from `ParallelEngine`s (see
    /// [`Metrics::link_shard_timings`]).
    shard_sinks: Mutex<Vec<(String, Arc<ShardTimings>)>>,
    /// Per-model fusion statistics from `FusedEngine`s (see
    /// [`Metrics::link_fusion_stats`]); compile-time constants, stored
    /// once and re-serialized per snapshot.
    fusion_stats: Mutex<Vec<(String, FusionStats)>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            shard_sinks: Mutex::new(Vec::new()),
            fusion_stats: Mutex::new(Vec::new()),
        }
    }

    /// Link the compile-time fusion statistics of a block-compiled
    /// engine so they appear in [`Metrics::snapshot`] under
    /// `fusion.<model>`. Re-linking the same model replaces the
    /// previous entry.
    pub fn link_fusion_stats(&self, model: &str, stats: FusionStats) {
        let mut sinks = self.fusion_stats.lock().expect("fusion stats poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = stats;
        } else {
            sinks.push((model.to_string(), stats));
        }
    }

    /// Link the per-shard timing counters of a batch-sharded engine so
    /// they appear in [`Metrics::snapshot`] under `shards.<model>`.
    /// Re-linking the same model name replaces the previous sink.
    pub fn link_shard_timings(&self, model: &str, sink: Arc<ShardTimings>) {
        let mut sinks = self.shard_sinks.lock().expect("shard sinks poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = sink;
        } else {
            sinks.push((model.to_string(), sink));
        }
    }

    fn bucket_of(latency_secs: f64) -> usize {
        // Bucket i covers [1µs·1.35^i, 1µs·1.35^{i+1}).
        let us = (latency_secs * 1e6).max(1.0);
        let i = (us.ln() / 1.35f64.ln()).floor() as isize;
        i.clamp(0, N_BUCKETS as isize - 1) as usize
    }

    fn bucket_upper_secs(i: usize) -> f64 {
        1e-6 * 1.35f64.powi(i as i32 + 1)
    }

    pub fn observe_latency(&self, latency_secs: f64) {
        let b = Self::bucket_of(latency_secs);
        self.latency_buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, batch_size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(batch_size as u64, Ordering::Relaxed);
    }

    /// Estimated latency quantile (upper edge of the containing bucket).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut cum = 0;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_secs(i);
            }
        }
        Self::bucket_upper_secs(N_BUCKETS - 1)
    }

    /// Mean batch size over all served batches.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> Json {
        let mut j = Json::obj()
            .set("requests", self.requests.load(Ordering::Relaxed))
            .set("responses", self.responses.load(Ordering::Relaxed))
            .set("errors", self.errors.load(Ordering::Relaxed))
            .set("batches", self.batches.load(Ordering::Relaxed))
            .set("mean_batch_size", self.mean_batch_size())
            .set("latency_p50_ms", self.latency_quantile(0.50) * 1e3)
            .set("latency_p99_ms", self.latency_quantile(0.99) * 1e3);
        let sinks = self.shard_sinks.lock().expect("shard sinks poisoned");
        if !sinks.is_empty() {
            let mut shards = Json::obj();
            for (model, sink) in sinks.iter() {
                shards = shards.set(model, sink.to_json());
            }
            j = j.set("shards", shards);
        }
        drop(sinks);
        let stats = self.fusion_stats.lock().expect("fusion stats poisoned");
        if !stats.is_empty() {
            let mut fusion = Json::obj();
            for (model, s) in stats.iter() {
                fusion = fusion.set(model, s.to_json());
            }
            j = j.set("fusion", fusion);
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone() {
        assert!(Metrics::bucket_of(1e-6) <= Metrics::bucket_of(1e-3));
        assert!(Metrics::bucket_of(1e-3) <= Metrics::bucket_of(1.0));
        assert_eq!(Metrics::bucket_of(0.0), 0);
        assert_eq!(Metrics::bucket_of(1e9), N_BUCKETS - 1);
    }

    #[test]
    fn quantiles_bracket_observations() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(0.001);
        }
        for _ in 0..10 {
            m.observe_latency(0.1);
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 > 0.0005 && p50 < 0.005, "p50 {p50}");
        assert!(p99 > 0.05, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_quantile_is_zero() {
        assert_eq!(Metrics::new().latency_quantile(0.5), 0.0);
    }

    #[test]
    fn shard_sinks_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("shards").is_none(), "no sinks, no key");

        let sink = Arc::new(ShardTimings::new());
        sink.record(&[0.001, 0.002, 0.004, 0.001]);
        m.link_shard_timings("mlp", Arc::clone(&sink));
        let s = m.snapshot();
        assert_eq!(s.path(&["shards", "mlp", "runs"]).unwrap().as_u64(), Some(4));
        assert_eq!(s.path(&["shards", "mlp", "batches"]).unwrap().as_u64(), Some(1));
        assert!(s.path(&["shards", "mlp", "max_shard_ms"]).unwrap().as_f64().unwrap() >= 3.9);

        // Re-linking the same model replaces, not duplicates.
        m.link_shard_timings("mlp", Arc::new(ShardTimings::new()));
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["shards", "mlp", "runs"]).unwrap().as_u64(), Some(0));
    }

    #[test]
    fn fusion_stats_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("fusion").is_none(), "no stats, no key");

        let stats = FusionStats {
            n_ops: 100,
            n_dot_runs: 10,
            n_axpy_runs: 5,
            n_singletons: 4,
            fused_ops: 96,
            max_run_len: 20,
        };
        m.link_fusion_stats("mlp", stats.clone());
        let s = m.snapshot();
        assert_eq!(s.path(&["fusion", "mlp", "ops"]).unwrap().as_u64(), Some(100));
        assert_eq!(s.path(&["fusion", "mlp", "macro_ops"]).unwrap().as_u64(), Some(19));
        assert_eq!(s.path(&["fusion", "mlp", "max_run_len"]).unwrap().as_u64(), Some(20));

        // Re-linking the same model replaces, not duplicates.
        m.link_fusion_stats("mlp", FusionStats { n_ops: 1, n_singletons: 1, ..stats });
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["fusion", "mlp", "ops"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        let s = m.snapshot();
        assert_eq!(s.get("batches").unwrap().as_u64(), Some(2));
    }
}
