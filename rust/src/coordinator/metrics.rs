//! Serving metrics: request counters, batch-size accounting, fixed-bucket
//! latency histograms (end-to-end, queue-wait, and compute — the split
//! that tells an SLO violation caused by queueing from one caused by a
//! slow engine), shed/deadline-miss counters from admission control,
//! linked per-shard timing sinks from batch-sharded engines, per-model
//! fusion statistics from block-compiled engines, and live
//! activation-skip counters from the compiled schedules. Lock-free on
//! the hot path (atomics only; the sink lists are only locked at link and
//! snapshot time); snapshots serialize to JSON.
//!
//! Fault containment adds its own counters: `engine_faults` (contained
//! engine panics), `worker_restarts` (thread-pool workers respawned
//! after a job panic — shared with pools via
//! [`Metrics::worker_restart_sink`]), `quarantined` (artifacts renamed
//! aside by the registry), and per-model circuit-breaker state (linked
//! via [`Metrics::link_breaker`], summarized by [`Metrics::health_json`]
//! for the TCP `health` command).

use super::breaker::Breaker;
use super::overload::OverloadControl;
use crate::exec::fused::{FusionStats, SkipCounters};
use crate::exec::parallel::ShardTimings;
use crate::exec::tiled::TiledStats;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Histogram bucket count: log-spaced buckets from 1 µs to ~17 s.
const N_BUCKETS: usize = 48;

/// A fixed-bucket latency histogram: 48 log-spaced buckets covering
/// 1 µs … ~17 s (bucket `i` covers the half-open range
/// `[1µs·1.35^i, 1µs·1.35^{i+1})`; bucket 0 additionally absorbs
/// everything below 1 µs). The bucket edges are precomputed once —
/// every snapshot and every process sees the same grid, so quantiles
/// are comparable across runs — and bucketing binary-searches the edge
/// table, so an observation exactly on an edge lands in the bucket
/// whose *lower* edge it is (the old ln-ratio + floor computation could
/// place edge values one bucket low through rounding). Quantile
/// estimates report the upper edge of the containing bucket (a ≤ 35%
/// overestimate, never an underestimate).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Upper bucket edges in seconds (`edges[i]` closes bucket `i`),
    /// computed once so every `bucket_of` call agrees bit-for-bit.
    fn edges() -> &'static [f64; N_BUCKETS] {
        static EDGES: OnceLock<[f64; N_BUCKETS]> = OnceLock::new();
        EDGES.get_or_init(|| std::array::from_fn(|i| 1e-6 * 1.35f64.powi(i as i32 + 1)))
    }

    fn bucket_of(latency_secs: f64) -> usize {
        Self::edges()
            .partition_point(|&upper| upper <= latency_secs)
            .min(N_BUCKETS - 1)
    }

    fn bucket_upper_secs(i: usize) -> f64 {
        Self::edges()[i]
    }

    pub fn observe(&self, secs: f64) {
        self.observe_n(secs, 1);
    }

    /// Record `n` observations of the same value (e.g. a batch's compute
    /// time weighted by the number of requests it served).
    pub fn observe_n(&self, secs: f64, n: u64) {
        let b = Self::bucket_of(secs);
        self.buckets[b].fetch_add(n, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Estimated quantile (upper edge of the containing bucket); 0.0 when
    /// empty. Snapshots the counters into a stack array — no allocation
    /// per scrape.
    pub fn quantile(&self, q: f64) -> f64 {
        let mut counts = [0u64; N_BUCKETS];
        for (c, b) in counts.iter_mut().zip(self.buckets.iter()) {
            *c = b.load(Ordering::Relaxed);
        }
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut cum = 0;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper_secs(i);
            }
        }
        Self::bucket_upper_secs(N_BUCKETS - 1)
    }

    /// p50/p95/p99 in milliseconds as a JSON object (the shape the TCP
    /// `metrics` command and the loadgen report share).
    pub fn quantiles_ms_json(&self) -> Json {
        Json::obj()
            .set("p50", self.quantile(0.50) * 1e3)
            .set("p95", self.quantile(0.95) * 1e3)
            .set("p99", self.quantile(0.99) * 1e3)
    }
}

pub struct Metrics {
    pub requests: AtomicU64,
    pub responses: AtomicU64,
    pub errors: AtomicU64,
    /// Requests rejected by admission control (`QueueFull`): the queue
    /// was at `max_queue` when they arrived. No compute was spent.
    pub shed: AtomicU64,
    /// Requests dropped at dispatch because their deadline had already
    /// passed while they waited in the queue.
    pub deadline_misses: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// Responses served from a degradation-ladder rung below the top
    /// tier (see `coordinator::overload`); always 0 when no model has a
    /// ladder or the ladders never engage.
    pub degraded: AtomicU64,
    /// Engine invocations that panicked and were contained by the
    /// dispatcher's `catch_unwind` (a batch panic and each panicking
    /// individual re-dispatch both count one).
    pub engine_faults: AtomicU64,
    /// Artifacts the registry quarantined (renamed `*.sfb.quarantined`)
    /// after failing CRC/validation or the hot-swap probe.
    pub quarantined: AtomicU64,
    /// Thread-pool workers respawned after a panicking job. `Arc`'d so
    /// pools can bump it directly (see [`Metrics::worker_restart_sink`]).
    worker_restarts: Arc<AtomicU64>,
    /// Per-model circuit breakers (see [`Metrics::link_breaker`]): live
    /// handles read at snapshot time for `breaker.<model>` state.
    breakers: Mutex<Vec<(String, Arc<Breaker>)>>,
    /// End-to-end latency (enqueue → reply).
    latency: Histogram,
    /// Queue wait (enqueue → batch dispatch).
    queue_wait: Histogram,
    /// Engine compute time per batch, weighted by batch size so request
    /// quantiles are request-weighted, not batch-weighted.
    compute: Histogram,
    /// Per-model shard-timing sinks from `ParallelEngine`s (see
    /// [`Metrics::link_shard_timings`]).
    shard_sinks: Mutex<Vec<(String, Arc<ShardTimings>)>>,
    /// Per-model fusion statistics from `FusedEngine`s (see
    /// [`Metrics::link_fusion_stats`]); compile-time constants, stored
    /// once and re-serialized per snapshot.
    fusion_stats: Mutex<Vec<(String, FusionStats)>>,
    /// Per-model tiling statistics from `TiledEngine`s (see
    /// [`Metrics::link_tiled_stats`]); compile-time constants like the
    /// fusion stats.
    tiled_stats: Mutex<Vec<(String, TiledStats)>>,
    /// Per-model live activation-skip counters from the compiled
    /// schedules (see [`Metrics::link_skip_counters`]): unlike the
    /// fusion/tiled stats these are run-time counters, read fresh at
    /// every snapshot.
    skip_sinks: Mutex<Vec<(String, Arc<SkipCounters>)>>,
    /// Per-model dispatched microkernel tag ("scalar" | "avx2"; see
    /// [`Metrics::link_kernel`]) — which `exec::simd` path the deployed
    /// engine actually runs.
    kernels: Mutex<Vec<(String, &'static str)>>,
    /// Per-model overload controllers (see [`Metrics::link_ladder`]):
    /// live handles read at snapshot time for `ladder.<model>` state
    /// (active rung, admit limit, step counts). Only laddered models
    /// are linked, so ladder-less snapshots keep their exact shape.
    ladders: Mutex<Vec<(String, Arc<OverloadControl>)>>,
    /// Registry state provider (see [`Metrics::link_registry`]): called
    /// at snapshot time to embed the model registry's tier/version view
    /// under the `registry` key.
    registry_sink: Mutex<Option<RegistrySink>>,
}

/// Snapshot provider linked by the model registry: returns its JSON
/// state (models, versions, tiers, resident bytes) on demand.
pub type RegistrySink = Arc<dyn Fn() -> Json + Send + Sync>;

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            engine_faults: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            worker_restarts: Arc::new(AtomicU64::new(0)),
            breakers: Mutex::new(Vec::new()),
            latency: Histogram::new(),
            queue_wait: Histogram::new(),
            compute: Histogram::new(),
            shard_sinks: Mutex::new(Vec::new()),
            fusion_stats: Mutex::new(Vec::new()),
            tiled_stats: Mutex::new(Vec::new()),
            skip_sinks: Mutex::new(Vec::new()),
            kernels: Mutex::new(Vec::new()),
            ladders: Mutex::new(Vec::new()),
            registry_sink: Mutex::new(None),
        }
    }

    /// Link a model's overload controller so its ladder state appears in
    /// [`Metrics::snapshot`] under `ladder.<model>`. Re-linking the same
    /// model replaces the previous entry (hot-swaps install a fresh
    /// controller, same lifecycle as breakers).
    pub fn link_ladder(&self, model: &str, ctl: Arc<OverloadControl>) {
        let mut sinks = self.ladders.lock().expect("ladder sinks poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = ctl;
        } else {
            sinks.push((model.to_string(), ctl));
        }
    }

    /// Drop a model's ladder link (undeploy, or a hot-swap to a
    /// ladder-less deployment).
    pub fn unlink_ladder(&self, model: &str) {
        self.ladders
            .lock()
            .expect("ladder sinks poisoned")
            .retain(|(name, _)| name != model);
    }

    /// Link the model registry's snapshot provider so its state appears
    /// in [`Metrics::snapshot`] under `registry`. Re-linking replaces.
    pub fn link_registry(&self, sink: RegistrySink) {
        *self.registry_sink.lock().expect("registry sink poisoned") = Some(sink);
    }

    /// Link a model's circuit breaker so its state appears in
    /// [`Metrics::snapshot`] under `breaker.<model>` and in
    /// [`Metrics::health_json`]. Re-linking the same model replaces the
    /// previous entry (hot-swaps install a fresh breaker).
    pub fn link_breaker(&self, model: &str, breaker: Arc<Breaker>) {
        let mut sinks = self.breakers.lock().expect("breaker sinks poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = breaker;
        } else {
            sinks.push((model.to_string(), breaker));
        }
    }

    /// Drop a model's breaker link (undeploy).
    pub fn unlink_breaker(&self, model: &str) {
        self.breakers
            .lock()
            .expect("breaker sinks poisoned")
            .retain(|(name, _)| name != model);
    }

    /// Shared restart counter for thread pools (see
    /// `util::threadpool::SupervisionPolicy::restart_sink`): respawns
    /// bumped there surface as `worker_restarts` in snapshots.
    pub fn worker_restart_sink(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.worker_restarts)
    }

    /// The TCP `health` command's view: fault counters plus per-model
    /// breaker detail (`state`, `consecutive_faults`, `trips`, and an
    /// `unhealthy` flag that is true unless the breaker is closed).
    pub fn health_json(&self) -> Json {
        let mut j = Json::obj()
            .set("engine_faults", self.engine_faults.load(Ordering::Relaxed))
            .set("worker_restarts", self.worker_restarts.load(Ordering::Relaxed))
            .set("quarantined", self.quarantined.load(Ordering::Relaxed));
        let breakers = self.breakers.lock().expect("breaker sinks poisoned");
        let mut models = Json::obj();
        for (model, b) in breakers.iter() {
            let state = b.state();
            models = models.set(
                model,
                Json::obj()
                    .set("state", state.name())
                    .set("consecutive_faults", b.consecutive_faults() as u64)
                    .set("trips", b.trips())
                    .set("unhealthy", state != super::breaker::BreakerState::Closed),
            );
        }
        j = j.set("models", models);
        j
    }

    /// Link the compile-time fusion statistics of a block-compiled
    /// engine so they appear in [`Metrics::snapshot`] under
    /// `fusion.<model>`. Re-linking the same model replaces the
    /// previous entry.
    pub fn link_fusion_stats(&self, model: &str, stats: FusionStats) {
        let mut sinks = self.fusion_stats.lock().expect("fusion stats poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = stats;
        } else {
            sinks.push((model.to_string(), stats));
        }
    }

    /// Link the compile-time tiling statistics of a cache-tiled engine
    /// so they appear in [`Metrics::snapshot`] under `tiled.<model>`.
    /// Re-linking the same model replaces the previous entry.
    pub fn link_tiled_stats(&self, model: &str, stats: TiledStats) {
        let mut sinks = self.tiled_stats.lock().expect("tiled stats poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = stats;
        } else {
            sinks.push((model.to_string(), stats));
        }
    }

    /// Link the live activation-skip counters of a compiled-schedule
    /// engine so they appear in [`Metrics::snapshot`] under
    /// `skips.<model>` and merged into the model's `fusion`/`tiled`
    /// entry. Re-linking the same model replaces the previous sink.
    pub fn link_skip_counters(&self, model: &str, counters: Arc<SkipCounters>) {
        let mut sinks = self.skip_sinks.lock().expect("skip sinks poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = counters;
        } else {
            sinks.push((model.to_string(), counters));
        }
    }

    /// Record which microkernel a deployed model dispatches to, so it
    /// appears in [`Metrics::snapshot`] under `kernel.<model>`.
    /// Re-linking the same model replaces the previous entry.
    pub fn link_kernel(&self, model: &str, kernel: &'static str) {
        let mut sinks = self.kernels.lock().expect("kernel tags poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = kernel;
        } else {
            sinks.push((model.to_string(), kernel));
        }
    }

    /// Link the per-shard timing counters of a batch-sharded engine so
    /// they appear in [`Metrics::snapshot`] under `shards.<model>`.
    /// Re-linking the same model name replaces the previous sink.
    pub fn link_shard_timings(&self, model: &str, sink: Arc<ShardTimings>) {
        let mut sinks = self.shard_sinks.lock().expect("shard sinks poisoned");
        if let Some(entry) = sinks.iter_mut().find(|(name, _)| name == model) {
            entry.1 = sink;
        } else {
            sinks.push((model.to_string(), sink));
        }
    }

    pub fn observe_latency(&self, latency_secs: f64) {
        self.latency.observe(latency_secs);
    }

    pub fn observe_queue_wait(&self, wait_secs: f64) {
        self.queue_wait.observe(wait_secs);
    }

    /// Record one batch's engine time, weighted by the `n` requests it
    /// served.
    pub fn observe_compute(&self, compute_secs: f64, n: usize) {
        self.compute.observe_n(compute_secs, n as u64);
    }

    pub fn record_batch(&self, batch_size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(batch_size as u64, Ordering::Relaxed);
    }

    /// Estimated end-to-end latency quantile (upper edge of the
    /// containing bucket).
    pub fn latency_quantile(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    /// Estimated queue-wait quantile.
    pub fn queue_wait_quantile(&self, q: f64) -> f64 {
        self.queue_wait.quantile(q)
    }

    /// Estimated compute-time quantile (request-weighted).
    pub fn compute_quantile(&self, q: f64) -> f64 {
        self.compute.quantile(q)
    }

    /// Mean batch size over all served batches.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn snapshot(&self) -> Json {
        let mut j = Json::obj()
            .set("requests", self.requests.load(Ordering::Relaxed))
            .set("responses", self.responses.load(Ordering::Relaxed))
            .set("errors", self.errors.load(Ordering::Relaxed))
            .set("shed", self.shed.load(Ordering::Relaxed))
            .set("deadline_misses", self.deadline_misses.load(Ordering::Relaxed))
            .set("degraded", self.degraded.load(Ordering::Relaxed))
            .set("engine_faults", self.engine_faults.load(Ordering::Relaxed))
            .set("worker_restarts", self.worker_restarts.load(Ordering::Relaxed))
            .set("quarantined", self.quarantined.load(Ordering::Relaxed))
            .set("batches", self.batches.load(Ordering::Relaxed))
            .set("mean_batch_size", self.mean_batch_size())
            .set("latency_ms", self.latency.quantiles_ms_json())
            .set("queue_wait_ms", self.queue_wait.quantiles_ms_json())
            .set("compute_ms", self.compute.quantiles_ms_json())
            // Kept for dashboards reading the flat pre-histogram keys.
            .set("latency_p50_ms", self.latency.quantile(0.50) * 1e3)
            .set("latency_p99_ms", self.latency.quantile(0.99) * 1e3);
        let sinks = self.shard_sinks.lock().expect("shard sinks poisoned");
        if !sinks.is_empty() {
            let mut shards = Json::obj();
            for (model, sink) in sinks.iter() {
                shards = shards.set(model, sink.to_json());
            }
            j = j.set("shards", shards);
        }
        drop(sinks);
        let skips = self.skip_sinks.lock().expect("skip sinks poisoned");
        let skip_json = |model: &str, entry: Json| -> Json {
            match skips.iter().find(|(name, _)| name == model) {
                Some((_, c)) => entry
                    .set("axpy_skip_checked", c.checked())
                    .set("axpy_skipped", c.skipped())
                    .set("skip_rate", c.skip_rate()),
                None => entry,
            }
        };
        let stats = self.fusion_stats.lock().expect("fusion stats poisoned");
        if !stats.is_empty() {
            let mut fusion = Json::obj();
            for (model, s) in stats.iter() {
                fusion = fusion.set(model, skip_json(model, s.to_json()));
            }
            j = j.set("fusion", fusion);
        }
        drop(stats);
        let stats = self.tiled_stats.lock().expect("tiled stats poisoned");
        if !stats.is_empty() {
            let mut tiled = Json::obj();
            for (model, s) in stats.iter() {
                tiled = tiled.set(model, skip_json(model, s.to_json()));
            }
            j = j.set("tiled", tiled);
        }
        drop(stats);
        if !skips.is_empty() {
            let mut sk = Json::obj();
            for (model, c) in skips.iter() {
                sk = sk.set(model, c.to_json());
            }
            j = j.set("skips", sk);
        }
        drop(skips);
        let kernels = self.kernels.lock().expect("kernel tags poisoned");
        if !kernels.is_empty() {
            let mut k = Json::obj();
            for (model, tag) in kernels.iter() {
                k = k.set(model, *tag);
            }
            j = j.set("kernel", k);
        }
        drop(kernels);
        let breakers = self.breakers.lock().expect("breaker sinks poisoned");
        if !breakers.is_empty() {
            let mut b = Json::obj();
            for (model, breaker) in breakers.iter() {
                b = b.set(model, breaker.state().name());
            }
            j = j.set("breaker", b);
        }
        drop(breakers);
        let ladders = self.ladders.lock().expect("ladder sinks poisoned");
        if !ladders.is_empty() {
            let mut l = Json::obj();
            for (model, ctl) in ladders.iter() {
                l = l.set(model, ctl.snapshot());
            }
            j = j.set("ladder", l);
        }
        drop(ladders);
        let sink = self.registry_sink.lock().expect("registry sink poisoned");
        if let Some(sink) = sink.as_ref() {
            j = j.set("registry", sink());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone() {
        assert!(Histogram::bucket_of(1e-6) <= Histogram::bucket_of(1e-3));
        assert!(Histogram::bucket_of(1e-3) <= Histogram::bucket_of(1.0));
        assert_eq!(Histogram::bucket_of(0.0), 0);
        assert_eq!(Histogram::bucket_of(1e9), N_BUCKETS - 1);
    }

    #[test]
    fn edge_observations_land_in_the_bucket_they_open() {
        // Half-open buckets: a value exactly on an edge belongs to the
        // bucket whose lower edge it is (the ln-ratio + floor version
        // could misplace it one bucket low through fp rounding).
        for i in 0..N_BUCKETS - 1 {
            let edge = Histogram::bucket_upper_secs(i);
            assert_eq!(Histogram::bucket_of(edge), i + 1, "edge {i} opens bucket {}", i + 1);
            assert_eq!(
                Histogram::bucket_of(edge * (1.0 - 1e-12)),
                i,
                "just under edge {i} stays in bucket {i}"
            );
        }
        let top = Histogram::bucket_upper_secs(N_BUCKETS - 1);
        assert_eq!(Histogram::bucket_of(top), N_BUCKETS - 1, "top edge clamps");
    }

    #[test]
    fn quantiles_bracket_observations() {
        let m = Metrics::new();
        for _ in 0..90 {
            m.observe_latency(0.001);
        }
        for _ in 0..10 {
            m.observe_latency(0.1);
        }
        let p50 = m.latency_quantile(0.5);
        let p99 = m.latency_quantile(0.99);
        assert!(p50 > 0.0005 && p50 < 0.005, "p50 {p50}");
        assert!(p99 > 0.05, "p99 {p99}");
        assert!(p50 <= p99);
    }

    #[test]
    fn empty_quantile_is_zero() {
        assert_eq!(Metrics::new().latency_quantile(0.5), 0.0);
        assert_eq!(Histogram::new().quantile(0.99), 0.0);
    }

    #[test]
    fn weighted_observation_counts() {
        let h = Histogram::new();
        h.observe_n(0.010, 7);
        h.observe(0.010);
        assert_eq!(h.count(), 8);
        // All mass in one bucket: every quantile reports its upper edge.
        assert_eq!(h.quantile(0.5), h.quantile(0.99));
    }

    #[test]
    fn queue_wait_and_compute_split_in_snapshot() {
        let m = Metrics::new();
        m.observe_queue_wait(0.002);
        m.observe_compute(0.020, 4);
        let s = m.snapshot();
        let qw = s.path(&["queue_wait_ms", "p50"]).unwrap().as_f64().unwrap();
        let cp = s.path(&["compute_ms", "p50"]).unwrap().as_f64().unwrap();
        assert!(qw > 1.0 && qw < 10.0, "queue wait p50 {qw}");
        assert!(cp > 10.0 && cp < 100.0, "compute p50 {cp}");
        assert!(s.path(&["latency_ms", "p95"]).is_some());
        assert_eq!(s.get("shed").unwrap().as_u64(), Some(0));
        assert_eq!(s.get("deadline_misses").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn shed_counters_serialize() {
        let m = Metrics::new();
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.deadline_misses.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.get("shed").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("deadline_misses").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn shard_sinks_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("shards").is_none(), "no sinks, no key");

        let sink = Arc::new(ShardTimings::new());
        sink.record(&[0.001, 0.002, 0.004, 0.001]);
        m.link_shard_timings("mlp", Arc::clone(&sink));
        let s = m.snapshot();
        assert_eq!(s.path(&["shards", "mlp", "runs"]).unwrap().as_u64(), Some(4));
        assert_eq!(s.path(&["shards", "mlp", "batches"]).unwrap().as_u64(), Some(1));
        assert!(s.path(&["shards", "mlp", "max_shard_ms"]).unwrap().as_f64().unwrap() >= 3.9);

        // Re-linking the same model replaces, not duplicates.
        m.link_shard_timings("mlp", Arc::new(ShardTimings::new()));
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["shards", "mlp", "runs"]).unwrap().as_u64(), Some(0));
    }

    #[test]
    fn fusion_stats_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("fusion").is_none(), "no stats, no key");

        let stats = FusionStats {
            n_ops: 100,
            n_dot_runs: 10,
            n_axpy_runs: 5,
            n_singletons: 4,
            fused_ops: 96,
            max_run_len: 20,
        };
        m.link_fusion_stats("mlp", stats.clone());
        let s = m.snapshot();
        assert_eq!(s.path(&["fusion", "mlp", "ops"]).unwrap().as_u64(), Some(100));
        assert_eq!(s.path(&["fusion", "mlp", "macro_ops"]).unwrap().as_u64(), Some(19));
        assert_eq!(s.path(&["fusion", "mlp", "max_run_len"]).unwrap().as_u64(), Some(20));

        // Re-linking the same model replaces, not duplicates.
        m.link_fusion_stats("mlp", FusionStats { n_ops: 1, n_singletons: 1, ..stats });
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["fusion", "mlp", "ops"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn tiled_stats_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("tiled").is_none(), "no stats, no key");

        let stats = TiledStats {
            n_ops: 200,
            m: 16,
            n_segments: 12,
            n_macro_ops: 40,
            fills: 90,
            spills: 30,
            max_live: 15,
            sum_live: 120,
        };
        m.link_tiled_stats("mlp", stats.clone());
        let s = m.snapshot();
        assert_eq!(s.path(&["tiled", "mlp", "segments"]).unwrap().as_u64(), Some(12));
        assert_eq!(s.path(&["tiled", "mlp", "m"]).unwrap().as_u64(), Some(16));
        assert_eq!(s.path(&["tiled", "mlp", "fills"]).unwrap().as_u64(), Some(90));
        let mean = s.path(&["tiled", "mlp", "mean_live"]).unwrap().as_f64().unwrap();
        assert!((mean - 10.0).abs() < 1e-9, "mean live {mean}");

        // Re-linking the same model replaces, not duplicates.
        m.link_tiled_stats("mlp", TiledStats { n_segments: 1, ..stats });
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["tiled", "mlp", "segments"]).unwrap().as_u64(), Some(1));
    }

    #[test]
    fn skip_counters_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("skips").is_none(), "no sinks, no key");

        let c = Arc::new(SkipCounters::default());
        c.checked.fetch_add(10, Ordering::Relaxed);
        c.skipped.fetch_add(4, Ordering::Relaxed);
        m.link_skip_counters("mlp", Arc::clone(&c));
        let s = m.snapshot();
        assert_eq!(
            s.path(&["skips", "mlp", "axpy_skip_checked"]).unwrap().as_u64(),
            Some(10)
        );
        assert_eq!(s.path(&["skips", "mlp", "axpy_skipped"]).unwrap().as_u64(), Some(4));
        let rate = s.path(&["skips", "mlp", "skip_rate"]).unwrap().as_f64().unwrap();
        assert!((rate - 0.4).abs() < 1e-9, "skip rate {rate}");

        // The counters are live run-time state, not a copy: the engine
        // bumps, the next snapshot sees it.
        c.skipped.fetch_add(1, Ordering::Relaxed);
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["skips", "mlp", "axpy_skipped"]).unwrap().as_u64(), Some(5));

        // Merged into the model's fusion/tiled entry when it has one.
        m.link_fusion_stats("mlp", FusionStats { n_ops: 10, ..FusionStats::default() });
        let s3 = m.snapshot();
        assert_eq!(s3.path(&["fusion", "mlp", "axpy_skipped"]).unwrap().as_u64(), Some(5));
        assert_eq!(s3.path(&["fusion", "mlp", "ops"]).unwrap().as_u64(), Some(10));

        // Re-linking the same model replaces, not duplicates.
        m.link_skip_counters("mlp", Arc::new(SkipCounters::default()));
        assert_eq!(
            m.snapshot().path(&["skips", "mlp", "axpy_skip_checked"]).unwrap().as_u64(),
            Some(0)
        );
    }

    #[test]
    fn kernel_tags_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("kernel").is_none(), "no tags, no key");
        m.link_kernel("mlp", "scalar");
        m.link_kernel("bert", "avx2");
        let s = m.snapshot();
        assert_eq!(s.path(&["kernel", "mlp"]).unwrap().as_str(), Some("scalar"));
        assert_eq!(s.path(&["kernel", "bert"]).unwrap().as_str(), Some("avx2"));

        // Re-linking the same model replaces, not duplicates.
        m.link_kernel("mlp", "avx2");
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["kernel", "mlp"]).unwrap().as_str(), Some("avx2"));
    }

    #[test]
    fn registry_sink_in_snapshot() {
        let m = Metrics::new();
        assert!(m.snapshot().get("registry").is_none(), "no sink, no key");
        m.link_registry(Arc::new(|| Json::obj().set("models", 2u64)));
        let s = m.snapshot();
        assert_eq!(s.path(&["registry", "models"]).unwrap().as_u64(), Some(2));
    }

    #[test]
    fn fault_counters_serialize() {
        let m = Metrics::new();
        m.engine_faults.fetch_add(2, Ordering::Relaxed);
        m.quarantined.fetch_add(1, Ordering::Relaxed);
        m.worker_restart_sink().fetch_add(4, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.get("engine_faults").unwrap().as_u64(), Some(2));
        assert_eq!(s.get("quarantined").unwrap().as_u64(), Some(1));
        assert_eq!(s.get("worker_restarts").unwrap().as_u64(), Some(4));
        let h = m.health_json();
        assert_eq!(h.get("engine_faults").unwrap().as_u64(), Some(2));
        assert_eq!(h.get("worker_restarts").unwrap().as_u64(), Some(4));
    }

    #[test]
    fn breaker_state_in_snapshot() {
        use super::super::breaker::{BreakerPolicy, BreakerState};
        let m = Metrics::new();
        assert!(m.snapshot().get("breaker").is_none(), "no breakers, no key");

        let b = Arc::new(Breaker::new(BreakerPolicy {
            fault_threshold: 1,
            cooldown: std::time::Duration::from_secs(60),
            hang_cap: None,
        }));
        m.link_breaker("mlp", Arc::clone(&b));
        let s = m.snapshot();
        assert_eq!(s.path(&["breaker", "mlp"]).unwrap().as_str(), Some("closed"));

        b.observe(true, std::time::Duration::ZERO);
        assert_eq!(b.state(), BreakerState::Open);
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["breaker", "mlp"]).unwrap().as_str(), Some("open"));
        let h = m.health_json();
        assert_eq!(
            h.path(&["models", "mlp", "unhealthy"]).unwrap().as_bool(),
            Some(true)
        );
        assert_eq!(h.path(&["models", "mlp", "trips"]).unwrap().as_u64(), Some(1));

        // Re-linking the same model replaces, not duplicates; unlink drops.
        m.link_breaker("mlp", Arc::new(Breaker::new(BreakerPolicy::default())));
        let s3 = m.snapshot();
        assert_eq!(s3.path(&["breaker", "mlp"]).unwrap().as_str(), Some("closed"));
        m.unlink_breaker("mlp");
        assert!(m.snapshot().get("breaker").is_none());
    }

    #[test]
    fn degraded_counter_serializes() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.get("degraded").unwrap().as_u64(), Some(0));
        m.degraded.fetch_add(5, Ordering::Relaxed);
        assert_eq!(m.snapshot().get("degraded").unwrap().as_u64(), Some(5));
    }

    #[test]
    fn ladder_state_in_snapshot() {
        use super::super::overload::{OverloadPolicy, Rung};
        use crate::exec::batch::BatchMatrix;
        use crate::exec::Engine;

        struct Id;
        impl Engine for Id {
            fn infer(&self, x: &BatchMatrix) -> BatchMatrix {
                x.clone()
            }
            fn name(&self) -> &'static str {
                "id"
            }
            fn n_inputs(&self) -> usize {
                1
            }
            fn n_outputs(&self) -> usize {
                1
            }
        }

        let m = Metrics::new();
        assert!(m.snapshot().get("ladder").is_none(), "no ladders, no key");

        let ladder = |labels: &[&str]| {
            Arc::new(OverloadControl::new(
                labels.iter().map(|l| Rung::new(Arc::new(Id), l.to_string(), None)).collect(),
                OverloadPolicy::default(),
            ))
        };
        m.link_ladder("mlp", ladder(&["fused-f32", "fused-i8"]));
        let s = m.snapshot();
        assert_eq!(s.path(&["ladder", "mlp", "rungs"]).unwrap().as_u64(), Some(2));
        assert_eq!(s.path(&["ladder", "mlp", "active"]).unwrap().as_u64(), Some(0));
        assert_eq!(
            s.path(&["ladder", "mlp", "active_label"]).unwrap().as_str(),
            Some("fused-f32")
        );
        assert_eq!(s.path(&["ladder", "mlp", "degraded"]).unwrap().as_bool(), Some(false));

        // Re-linking the same model replaces, not duplicates; unlink drops.
        m.link_ladder("mlp", ladder(&["tiled-f32", "tiled-i8", "interp-i8"]));
        let s2 = m.snapshot();
        assert_eq!(s2.path(&["ladder", "mlp", "rungs"]).unwrap().as_u64(), Some(3));
        m.unlink_ladder("mlp");
        assert!(m.snapshot().get("ladder").is_none());
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        let s = m.snapshot();
        assert_eq!(s.get("batches").unwrap().as_u64(), Some(2));
    }
}
