//! # sparseflow
//!
//! I/O-efficient sparse neural network inference, reproducing
//! *"A Theory of I/O-Efficient Sparse Neural Network Inference"*
//! (Gleinig, Ben-Nun, Hoefler, 2023).
//!
//! The crate is organized around the paper's pipeline:
//!
//! 1. [`ffnn`] — sparse FFNNs as weighted DAGs: generators (random MLPs,
//!    Compact Growth, BERT-like pruned encoder MLPs), topological orders of
//!    connections, extremal constructions, bandwidth.
//! 2. [`memory`] + [`sim`] — the two-level memory cost model (fast memory of
//!    size `M`, slow memory unlimited) and the Algorithm-1 inference
//!    simulator that counts read-/write-I/Os under LRU / RR / MIN eviction.
//! 3. [`bounds`] — Theorem-1 lower/upper bounds on I/Os.
//! 4. [`reorder`] — Connection Reordering: simulated annealing over
//!    topological connection orders (window moves, `2^{-Δ·t^σ}` updates).
//! 5. [`exec`] — real numeric engines: the streaming executor that runs a
//!    (reordered) connection order on batched inputs, the layer-wise CSR
//!    baseline (CSRMM), a dense reference, the batch-sharded
//!    [`exec::parallel::ParallelEngine`] running any of them on
//!    concurrent column shards (bit-identical to serial), the
//!    compressed quantized stream ([`exec::quant`]: delta/varint indices
//!    + per-group i8 weights, with a certified output-error bound), the
//!    fused block-compiled stream ([`exec::fused`]: run-length
//!    macro-ops + batch-tiled microkernels, bit-identical to the
//!    interpreter), and the cache-tiled slot-compiled stream
//!    ([`exec::tiled`]: liveness-segmented execution inside an `M`-slot
//!    block with explicit fill/spill I/Os at segment boundaries,
//!    bit-identical for every budget, autotuned through the simulator).
//! 6. [`runtime`] — PJRT client that loads AOT-compiled JAX/Pallas HLO
//!    artifacts and executes them from Rust, plus the zero-copy
//!    `sparseflow-bin-v1` model artifact ([`runtime::artifact`],
//!    [`runtime::mmap`]).
//! 6b. [`model`] — the unified model-loading API: [`model::Model::load`]
//!    sniffs JSON / quant-JSON / binary artifacts and builds serving
//!    variants through one constructor.
//! 7. [`coordinator`] — batched inference serving: request queue,
//!    deadline-aware dynamic batcher with admission control, engine
//!    router, worker pool, latency-split metrics, TCP front-end, and
//!    fault containment (engine-panic isolation, per-model circuit
//!    breakers, artifact quarantine with hot-swap rollback).
//! 8. [`loadgen`] — deterministic closed/open-loop load generator that
//!    measures the serving pipeline per engine variant, with seeded
//!    fault injection ([`exec::faults`]) for chaos runs.
//!
//! Everything is deterministic given a seed; see `util::rng`.
//!
//! ## Quickstart
//!
//! ```
//! use sparseflow::prelude::*;
//!
//! // A random sparse MLP per the paper's Appendix A (depth 4, width 8, 30% dense).
//! let mut rng = Pcg64::seed_from(42);
//! let net = random_mlp(&MlpSpec::new(4, 8, 0.30), &mut rng);
//! let order = two_optimal_order(&net);
//!
//! // Count I/Os with fast memory M=16 under Belady's MIN policy.
//! let stats = simulate(&net, &order, 16, PolicyKind::Min);
//! let b = theorem1_bounds(&net);
//! assert!(b.total_lower <= stats.total() && stats.total() <= b.total_upper);
//! ```

pub mod bench;
pub mod bounds;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod exec;
pub mod ffnn;
pub mod loadgen;
pub mod memory;
pub mod model;
pub mod reorder;
pub mod runtime;
pub mod sim;
pub mod util;

/// Convenient re-exports of the most common types and entry points.
pub mod prelude {
    pub use crate::bounds::{theorem1_bounds, Theorem1Bounds};
    pub use crate::exec::{
        csr::CsrLayer,
        fused::{FusedEngine, FusedProgram, FusionStats},
        layerwise::LayerwiseEngine,
        parallel::ParallelEngine,
        quant::{output_error_bound, QuantStreamEngine, QuantStreamProgram},
        stream::{StreamProgram, StreamingEngine},
        tiled::{AutotuneReport, TiledEngine, TiledProgram, TiledStats},
        Engine,
    };
    pub use crate::ffnn::{
        bert::{bert_mlp, BertSpec},
        compact_growth::{compact_growth, CompactGrowthSpec},
        generate::{random_mlp, MlpSpec},
        graph::{Conn, Ffnn, NeuronId},
        topo::{layerwise_order, two_optimal_order, ConnOrder},
    };
    pub use crate::memory::PolicyKind;
    pub use crate::model::{Format, LoadedModel, Model};
    pub use crate::reorder::annealing::{reorder, AnnealConfig, AnnealReport};
    pub use crate::sim::{simulate, IoStats};
    pub use crate::util::rng::Pcg64;
}
