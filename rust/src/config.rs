//! Config system: JSON config files under `configs/` merged with CLI
//! `--set key=value` overrides (dotted keys), giving every launcher
//! subcommand and bench a uniform, reproducible parameterization.

use crate::util::json::Json;
use std::path::Path;

/// A loaded configuration: a JSON object plus typed accessors with
/// defaults. Dotted-path lookups (`"anneal.iters"`) traverse nested
/// objects.
#[derive(Clone, Debug)]
pub struct Config {
    root: Json,
}

impl Config {
    pub fn empty() -> Config {
        Config { root: Json::obj() }
    }

    pub fn from_json(root: Json) -> Config {
        Config { root }
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let root = Json::from_file(path).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            matches!(root, Json::Obj(_)),
            "config {} must be a JSON object",
            path.display()
        );
        Ok(Config { root })
    }

    /// Apply a `key=value` override; dotted keys create nested objects.
    /// Values are parsed as JSON when possible, else taken as strings.
    pub fn set_override(&mut self, assignment: &str) -> anyhow::Result<()> {
        let (key, raw) = assignment
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value, got {assignment:?}"))?;
        let value = Json::parse(raw).unwrap_or_else(|_| Json::Str(raw.to_string()));
        let parts: Vec<&str> = key.split('.').collect();
        set_path(&mut self.root, &parts, value);
        Ok(())
    }

    fn lookup(&self, dotted: &str) -> Option<&Json> {
        let parts: Vec<&str> = dotted.split('.').collect();
        self.root.path(&parts)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.lookup(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.u64(key, default as u64) as usize
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.lookup(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.lookup(key).and_then(Json::as_bool).unwrap_or(default)
    }

    /// The batch-sharding worker knob (`workers` key): number of
    /// concurrent batch shards for `exec::parallel::ParallelEngine`.
    /// 0 is conventionally "auto" (resolved by the caller, e.g. via
    /// `bench::figures::workers_default`).
    pub fn workers(&self, default: usize) -> usize {
        self.usize("workers", default)
    }

    /// The numeric-precision knob (`precision` key): "f32" serves the
    /// full-precision stream engine, "i8" the compressed quantized
    /// stream (`exec::quant`). Orthogonal to `workers` sharding.
    pub fn precision(&self, default: &str) -> String {
        self.str("precision", default)
    }

    /// The op-stream-schedule knob (`schedule` key): "interp" serves the
    /// per-connection stream interpreter, "fused" the run-length
    /// block-compiled engine (`exec::fused`). Orthogonal to `workers`
    /// sharding; f32-only (see the composition matrix in `exec`).
    pub fn schedule(&self, default: &str) -> String {
        self.str("schedule", default)
    }

    /// The fast-memory knob (`fast_mem` key): slot budget `M` for the
    /// tiled schedule (`exec::tiled`). 0 = autotune the budget through
    /// the I/O simulator. Only meaningful with `schedule = "tiled"`.
    pub fn fast_mem(&self, default: usize) -> usize {
        self.usize("fast_mem", default)
    }

    /// The microkernel knob (`kernel` key): "auto" dispatches the
    /// compiled schedules (`exec::fused` / `exec::tiled`) to the best
    /// supported `exec::simd` path, "scalar" forces the portable one,
    /// "avx2" requires the AVX2 path (rejected on CPUs without it).
    pub fn kernel(&self, default: &str) -> String {
        self.str("kernel", default)
    }

    /// The activation-skip knob (`skip` key): whether the compiled
    /// schedules (`exec::fused` / `exec::tiled`, both precisions) skip
    /// AxpyRuns whose source activation row is entirely zero. Skipping
    /// is value-identical to not skipping; disable it to benchmark the
    /// unconditional stream or to rule the optimization out when
    /// debugging.
    pub fn skip(&self, default: bool) -> bool {
        self.bool("skip", default)
    }

    /// The admission-control knob (`max_queue` key): maximum queued
    /// requests per model before new submissions are shed with an
    /// explicit queue-full response. 0 = unbounded (no shedding).
    pub fn max_queue(&self, default: usize) -> usize {
        self.usize("max_queue", default)
    }

    /// The default-SLO knob (`deadline_ms` key): deadline budget in
    /// milliseconds applied to requests that carry none. 0 = no
    /// deadline.
    pub fn deadline_ms(&self, default: u64) -> u64 {
        self.u64("deadline_ms", default)
    }

    /// The registry model-directory knob (`model_dir` key): directory of
    /// `.sfb` artifacts scanned by `sparseflow serve --model-dir`.
    /// Empty = registry mode off.
    pub fn model_dir(&self, default: &str) -> String {
        self.str("model_dir", default)
    }

    /// The registry resident-budget knob (`resident_bytes` key): total
    /// bytes of hot (engine-resident) artifacts allowed before the LRU
    /// hot model is demoted to warm. 0 = unbounded.
    pub fn resident_bytes(&self, default: u64) -> u64 {
        self.u64("resident_bytes", default)
    }

    /// The circuit-breaker threshold knob (`breaker_faults` key):
    /// consecutive engine faults before a model's breaker opens and its
    /// requests are shed as unhealthy. 0 = breaker disabled (unless
    /// `hang_cap_ms` is set).
    pub fn breaker_faults(&self, default: u64) -> u64 {
        self.u64("breaker_faults", default)
    }

    /// The circuit-breaker cooldown knob (`breaker_cooldown_ms` key):
    /// milliseconds an open breaker waits before admitting a half-open
    /// probe request.
    pub fn breaker_cooldown_ms(&self, default: u64) -> u64 {
        self.u64("breaker_cooldown_ms", default)
    }

    /// The degradation-ladder knob (`ladder` key): ordered rungs below
    /// the served variant as comma-separated `schedule:precision` pairs
    /// (e.g. `"fused:i8"`), stepped down to under overload and probed
    /// back up when pressure clears. Empty = no ladder (overload sheds
    /// instead of degrading).
    pub fn ladder(&self, default: &str) -> String {
        self.str("ladder", default)
    }

    /// The hang-watchdog knob (`hang_cap_ms` key): hard wall-clock cap
    /// in milliseconds on a single engine invocation — an in-flight
    /// inference older than this opens the model's breaker (new work is
    /// shed while the dispatcher is wedged), and an over-cap completion
    /// counts as a fault. 0 = no cap.
    pub fn hang_cap_ms(&self, default: u64) -> u64 {
        self.u64("hang_cap_ms", default)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.lookup(key)
            .and_then(Json::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn json(&self) -> &Json {
        &self.root
    }
}

fn set_path(node: &mut Json, parts: &[&str], value: Json) {
    if parts.is_empty() {
        *node = value;
        return;
    }
    if !matches!(node, Json::Obj(_)) {
        *node = Json::obj();
    }
    if let Json::Obj(fields) = node {
        if let Some(f) = fields.iter_mut().find(|(k, _)| k == parts[0]) {
            set_path(&mut f.1, &parts[1..], value);
        } else {
            let mut child = Json::obj();
            set_path(&mut child, &parts[1..], value);
            fields.push((parts[0].to_string(), child));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_when_missing() {
        let c = Config::empty();
        assert_eq!(c.u64("anneal.iters", 7), 7);
        assert_eq!(c.str("policy", "min"), "min");
        assert!(c.bool("verbose", true));
    }

    #[test]
    fn overrides_nested() {
        let mut c = Config::empty();
        c.set_override("anneal.iters=5000").unwrap();
        c.set_override("anneal.sigma=0.2").unwrap();
        c.set_override("name=bert").unwrap();
        assert_eq!(c.u64("anneal.iters", 0), 5000);
        assert_eq!(c.f64("anneal.sigma", 0.0), 0.2);
        assert_eq!(c.str("name", ""), "bert");
    }

    #[test]
    fn override_replaces_file_value() {
        let mut c = Config::from_json(Json::obj().set("m", 100u64));
        c.set_override("m=200").unwrap();
        assert_eq!(c.u64("m", 0), 200);
    }

    #[test]
    fn workers_knob() {
        let mut c = Config::empty();
        assert_eq!(c.workers(8), 8, "default when unset");
        c.set_override("workers=4").unwrap();
        assert_eq!(c.workers(8), 4);
    }

    #[test]
    fn precision_knob() {
        let mut c = Config::empty();
        assert_eq!(c.precision("f32"), "f32", "default when unset");
        c.set_override("precision=i8").unwrap();
        assert_eq!(c.precision("f32"), "i8");
    }

    #[test]
    fn schedule_knob() {
        let mut c = Config::empty();
        assert_eq!(c.schedule("interp"), "interp", "default when unset");
        c.set_override("schedule=fused").unwrap();
        assert_eq!(c.schedule("interp"), "fused");
    }

    #[test]
    fn fast_mem_knob() {
        let mut c = Config::empty();
        assert_eq!(c.fast_mem(0), 0, "default when unset (0 = autotune)");
        c.set_override("fast_mem=128").unwrap();
        assert_eq!(c.fast_mem(0), 128);
    }

    #[test]
    fn kernel_knob() {
        let mut c = Config::empty();
        assert_eq!(c.kernel("auto"), "auto", "default when unset");
        c.set_override("kernel=scalar").unwrap();
        assert_eq!(c.kernel("auto"), "scalar");
    }

    #[test]
    fn skip_knob() {
        let mut c = Config::empty();
        assert!(c.skip(true), "default when unset (skip on)");
        c.set_override("skip=false").unwrap();
        assert!(!c.skip(true));
    }

    #[test]
    fn serving_slo_knobs() {
        let mut c = Config::empty();
        assert_eq!(c.max_queue(0), 0, "default when unset");
        assert_eq!(c.deadline_ms(0), 0, "default when unset");
        c.set_override("max_queue=256").unwrap();
        c.set_override("deadline_ms=50").unwrap();
        assert_eq!(c.max_queue(0), 256);
        assert_eq!(c.deadline_ms(0), 50);
    }

    #[test]
    fn registry_knobs() {
        let mut c = Config::empty();
        assert_eq!(c.model_dir(""), "", "default when unset (registry off)");
        assert_eq!(c.resident_bytes(0), 0, "default when unset (unbounded)");
        c.set_override("model_dir=models/").unwrap();
        c.set_override("resident_bytes=1048576").unwrap();
        assert_eq!(c.model_dir(""), "models/");
        assert_eq!(c.resident_bytes(0), 1 << 20);
    }

    #[test]
    fn fault_containment_knobs() {
        let mut c = Config::empty();
        assert_eq!(c.breaker_faults(3), 3, "default when unset");
        assert_eq!(c.breaker_cooldown_ms(1000), 1000, "default when unset");
        assert_eq!(c.hang_cap_ms(0), 0, "default when unset (no cap)");
        c.set_override("breaker_faults=5").unwrap();
        c.set_override("breaker_cooldown_ms=250").unwrap();
        c.set_override("hang_cap_ms=2000").unwrap();
        assert_eq!(c.breaker_faults(3), 5);
        assert_eq!(c.breaker_cooldown_ms(1000), 250);
        assert_eq!(c.hang_cap_ms(0), 2000);
    }

    #[test]
    fn ladder_knob() {
        let mut c = Config::empty();
        assert_eq!(c.ladder(""), "", "default when unset (no ladder)");
        c.set_override("ladder=fused:i8").unwrap();
        assert_eq!(c.ladder(""), "fused:i8");
    }

    #[test]
    fn bad_override_rejected() {
        let mut c = Config::empty();
        assert!(c.set_override("no-equals-sign").is_err());
    }

    #[test]
    fn string_fallback_for_nonjson() {
        let mut c = Config::empty();
        c.set_override("out=results/fig2.json").unwrap();
        assert_eq!(c.str("out", ""), "results/fig2.json");
    }
}
