//! The two-level memory model (paper §II): a fast memory holding at most
//! `M` same-size values and an unlimited slow memory. This module provides
//! the resident-set bookkeeping and the three eviction policies of the
//! paper — LRU, RR (round-robin) and MIN (Belady's optimal replacement,
//! trivially implementable offline once the connection order is fixed).

use crate::ffnn::graph::NeuronId;

/// Eviction policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Least-recently-used.
    Lru,
    /// Round-robin: a pointer cycles over memory slots; the value under
    /// the pointer is evicted and replaced, then the pointer advances.
    Rr,
    /// Belady's MIN: evict the resident value whose next use is farthest
    /// in the future (values never used again are preferred). Optimal for
    /// a fixed reference string [Belady 1966].
    Min,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "lru" => Some(PolicyKind::Lru),
            "rr" => Some(PolicyKind::Rr),
            "min" => Some(PolicyKind::Min),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Lru => "LRU",
            PolicyKind::Rr => "RR",
            PolicyKind::Min => "MIN",
        }
    }

    pub const ALL: [PolicyKind; 3] = [PolicyKind::Lru, PolicyKind::Rr, PolicyKind::Min];
}

/// Marker for "never used again" in MIN next-use tracking.
pub const NEVER: u32 = u32::MAX;

/// The set of neuron values resident in fast memory, with policy state.
///
/// Capacity is `M − 1`: one slot of the fast memory is transiently
/// occupied by the in-flight connection triple (see DESIGN.md §7), so at
/// most `M − 1` neuron values are resident while an update executes.
#[derive(Clone, Debug)]
pub struct ResidentSet {
    policy: PolicyKind,
    capacity: usize,
    /// Resident neurons, unordered (swap-remove on eviction).
    members: Vec<NeuronId>,
    /// Victim-selection key per member slot, kept parallel to `members`:
    /// last-touch time for LRU, next-use position for MIN (unused by RR).
    /// Keeping keys contiguous makes the eviction scan cache-friendly
    /// (§Perf: the scan dominated MIN/LRU simulation time).
    keys: Vec<u32>,
    /// Index into `members`, or `NEVER` if not resident.
    slot_of: Vec<u32>,
    /// RR pointer into `members`.
    rr_ptr: usize,
}

impl ResidentSet {
    pub fn new(policy: PolicyKind, m: usize, n_neurons: usize) -> ResidentSet {
        assert!(m >= 3, "the model requires M ≥ 3 (got {m})");
        let capacity = m - 1;
        ResidentSet {
            policy,
            capacity,
            members: Vec::with_capacity(capacity.min(n_neurons)),
            keys: Vec::with_capacity(capacity.min(n_neurons)),
            slot_of: vec![NEVER; n_neurons],
            rr_ptr: 0,
        }
    }

    /// Re-target an existing set (reusing allocations) for a new run.
    pub fn reconfigure(&mut self, policy: PolicyKind, m: usize, n_neurons: usize) {
        assert!(m >= 3, "the model requires M ≥ 3 (got {m})");
        self.reset();
        self.policy = policy;
        self.capacity = m - 1;
        if self.slot_of.len() != n_neurons {
            self.slot_of = vec![NEVER; n_neurons];
        }
    }

    /// Reset to empty without reallocating (reused across SA iterations).
    pub fn reset(&mut self) {
        for &v in &self.members {
            self.slot_of[v as usize] = NEVER;
        }
        self.members.clear();
        self.keys.clear();
        self.rr_ptr = 0;
    }

    #[inline]
    pub fn contains(&self, v: NeuronId) -> bool {
        self.slot_of[v as usize] != NEVER
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.members.len() >= self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn members(&self) -> &[NeuronId] {
        &self.members
    }

    /// Record a use of a resident value at time `now` with its next use at
    /// `next` (MIN bookkeeping; `NEVER` if it will not be used again).
    #[inline]
    pub fn touch(&mut self, v: NeuronId, now: u32, next: u32) {
        debug_assert!(self.contains(v));
        let slot = self.slot_of[v as usize] as usize;
        self.keys[slot] = match self.policy {
            PolicyKind::Lru => now,
            PolicyKind::Min => next,
            PolicyKind::Rr => 0,
        };
    }

    /// Insert a (non-resident) value; caller must have made room.
    #[inline]
    pub fn insert(&mut self, v: NeuronId, now: u32, next: u32) {
        debug_assert!(!self.contains(v));
        debug_assert!(!self.is_full(), "insert into full resident set");
        self.slot_of[v as usize] = self.members.len() as u32;
        self.members.push(v);
        self.keys.push(match self.policy {
            PolicyKind::Lru => now,
            PolicyKind::Min => next,
            PolicyKind::Rr => 0,
        });
    }

    /// Choose a victim according to the policy and remove it. `pinned`
    /// values (the endpoints of the in-flight connection) are skipped.
    ///
    /// Panics if every resident value is pinned (cannot happen for M ≥ 3:
    /// at most one endpoint is pinned while the other is being loaded).
    pub fn evict(&mut self, pinned: [NeuronId; 2]) -> NeuronId {
        debug_assert!(!self.members.is_empty());
        let victim_slot = match self.policy {
            // Branch-light explicit scans over the contiguous key array;
            // the pinned endpoints are fixed up afterwards (at most two
            // slots), keeping the hot loop comparison-only.
            PolicyKind::Lru => {
                let slot = scan_extreme::<true>(&self.keys);
                self.fixup_pinned::<true>(slot, pinned)
            }
            PolicyKind::Min => {
                let slot = scan_extreme::<false>(&self.keys);
                self.fixup_pinned::<false>(slot, pinned)
            }
            PolicyKind::Rr => {
                let n = self.members.len();
                let mut tries = 0;
                loop {
                    let i = self.rr_ptr % n;
                    let v = self.members[i];
                    if v != pinned[0] && v != pinned[1] {
                        self.rr_ptr = (i + 1) % n.max(1);
                        break i;
                    }
                    self.rr_ptr = (i + 1) % n;
                    tries += 1;
                    assert!(tries <= n, "all residents pinned");
                }
            }
        };
        self.remove_slot(victim_slot)
    }

    /// Remove a specific resident value (free deletion of dead values).
    pub fn remove(&mut self, v: NeuronId) {
        let slot = self.slot_of[v as usize];
        debug_assert_ne!(slot, NEVER);
        self.remove_slot(slot as usize);
    }

    /// Snapshot the policy-relevant state (members, keys, RR pointer) for
    /// checkpoint/restore in the annealing loop's suffix re-simulation.
    pub fn snapshot(&self) -> ResidentSnapshot {
        ResidentSnapshot {
            members: self.members.clone(),
            keys: self.keys.clone(),
            rr_ptr: self.rr_ptr,
        }
    }

    /// Restore a snapshot (rebuilds `slot_of`).
    pub fn restore(&mut self, snap: &ResidentSnapshot) {
        self.reset();
        self.members.extend_from_slice(&snap.members);
        self.keys.extend_from_slice(&snap.keys);
        self.rr_ptr = snap.rr_ptr;
        for (i, &v) in self.members.iter().enumerate() {
            self.slot_of[v as usize] = i as u32;
        }
    }

    /// Overwrite the MIN key of every member (used after restoring a
    /// checkpoint under a *different* order suffix, where the prefix
    /// next-use values are stale).
    pub fn rekey_min(&mut self, next_of: &[u32]) {
        debug_assert_eq!(self.policy, PolicyKind::Min);
        for (slot, &v) in self.members.iter().enumerate() {
            self.keys[slot] = next_of[v as usize];
        }
    }

    /// If the scan winner is pinned, rescan excluding pinned slots.
    #[inline]
    fn fixup_pinned<const MIN_SCAN: bool>(&self, slot: usize, pinned: [NeuronId; 2]) -> usize {
        let v = self.members[slot];
        if v != pinned[0] && v != pinned[1] {
            return slot;
        }
        let mut best = usize::MAX;
        let mut best_key = if MIN_SCAN { u32::MAX } else { 0u32 };
        for (i, (&m, &k)) in self.members.iter().zip(&self.keys).enumerate() {
            if m == pinned[0] || m == pinned[1] {
                continue;
            }
            let better = if MIN_SCAN { k <= best_key } else { k >= best_key };
            if better || best == usize::MAX {
                best = i;
                best_key = k;
            }
        }
        assert_ne!(best, usize::MAX, "all residents pinned");
        best
    }

    fn remove_slot(&mut self, slot: usize) -> NeuronId {
        let v = self.members.swap_remove(slot);
        self.keys.swap_remove(slot);
        self.slot_of[v as usize] = NEVER;
        if let Some(&moved) = self.members.get(slot) {
            self.slot_of[moved as usize] = slot as u32;
        }
        v
    }
}

/// Saved resident-set state (see [`ResidentSet::snapshot`]).
#[derive(Clone, Debug)]
pub struct ResidentSnapshot {
    members: Vec<NeuronId>,
    keys: Vec<u32>,
    rr_ptr: usize,
}

/// Index of the minimum (`MIN_SCAN = true`) or maximum key; simple
/// autovectorizable loop.
#[inline]
fn scan_extreme<const MIN_SCAN: bool>(keys: &[u32]) -> usize {
    debug_assert!(!keys.is_empty());
    let mut best = 0usize;
    let mut best_key = keys[0];
    for (i, &k) in keys.iter().enumerate().skip(1) {
        let better = if MIN_SCAN { k < best_key } else { k > best_key };
        if better {
            best = i;
            best_key = k;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing() {
        assert_eq!(PolicyKind::parse("LRU"), Some(PolicyKind::Lru));
        assert_eq!(PolicyKind::parse("min"), Some(PolicyKind::Min));
        assert_eq!(PolicyKind::parse("rr"), Some(PolicyKind::Rr));
        assert_eq!(PolicyKind::parse("fifo"), None);
    }

    #[test]
    fn capacity_is_m_minus_one() {
        let rs = ResidentSet::new(PolicyKind::Lru, 3, 10);
        assert_eq!(rs.capacity(), 2);
    }

    #[test]
    #[should_panic(expected = "M ≥ 3")]
    fn m_below_three_rejected() {
        ResidentSet::new(PolicyKind::Lru, 2, 10);
    }

    #[test]
    fn insert_contains_remove() {
        let mut rs = ResidentSet::new(PolicyKind::Lru, 5, 10);
        rs.insert(3, 0, 5);
        rs.insert(7, 1, 2);
        assert!(rs.contains(3) && rs.contains(7));
        assert_eq!(rs.len(), 2);
        rs.remove(3);
        assert!(!rs.contains(3));
        assert!(rs.contains(7));
        assert_eq!(rs.len(), 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut rs = ResidentSet::new(PolicyKind::Lru, 4, 10);
        rs.insert(0, 0, NEVER);
        rs.insert(1, 1, NEVER);
        rs.insert(2, 2, NEVER);
        rs.touch(0, 3, NEVER); // 0 becomes most recent; 1 is oldest
        let victim = rs.evict([NEVER, NEVER]);
        assert_eq!(victim, 1);
    }

    #[test]
    fn lru_respects_pins() {
        let mut rs = ResidentSet::new(PolicyKind::Lru, 4, 10);
        rs.insert(0, 0, NEVER);
        rs.insert(1, 1, NEVER);
        rs.insert(2, 2, NEVER);
        let victim = rs.evict([0, 1]); // oldest two pinned
        assert_eq!(victim, 2);
    }

    #[test]
    fn min_evicts_farthest_next_use() {
        let mut rs = ResidentSet::new(PolicyKind::Min, 4, 10);
        rs.insert(0, 0, 100);
        rs.insert(1, 0, 5);
        rs.insert(2, 0, 50);
        assert_eq!(rs.evict([NEVER, NEVER]), 0);
    }

    #[test]
    fn min_prefers_dead_values() {
        let mut rs = ResidentSet::new(PolicyKind::Min, 4, 10);
        rs.insert(0, 0, 10);
        rs.insert(1, 0, NEVER); // never used again
        rs.insert(2, 0, 3);
        assert_eq!(rs.evict([NEVER, NEVER]), 1);
    }

    #[test]
    fn rr_cycles() {
        let mut rs = ResidentSet::new(PolicyKind::Rr, 5, 10);
        for v in 0..4 {
            rs.insert(v, 0, NEVER);
        }
        let v1 = rs.evict([NEVER, NEVER]);
        rs.insert(8, 1, NEVER);
        let v2 = rs.evict([NEVER, NEVER]);
        assert_ne!(v1, v2, "RR pointer must advance");
    }

    #[test]
    fn reset_clears() {
        let mut rs = ResidentSet::new(PolicyKind::Lru, 5, 10);
        rs.insert(1, 0, NEVER);
        rs.insert(2, 0, NEVER);
        rs.reset();
        assert_eq!(rs.len(), 0);
        assert!(!rs.contains(1));
        rs.insert(1, 0, NEVER); // reusable after reset
        assert!(rs.contains(1));
    }

    #[test]
    fn swap_remove_keeps_slots_consistent() {
        let mut rs = ResidentSet::new(PolicyKind::Lru, 6, 10);
        for v in 0..5 {
            rs.insert(v, v, NEVER);
        }
        rs.remove(0); // last member swaps into slot 0
        assert!(!rs.contains(0));
        for v in 1..5 {
            assert!(rs.contains(v), "neuron {v} lost by swap_remove");
        }
        // Evicting everything still works.
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            seen.insert(rs.evict([NEVER, NEVER]));
        }
        assert_eq!(seen.len(), 4);
    }
}
