//! Shared machinery for the paper-figure benches (`rust/benches/fig*.rs`):
//! run the generate → simulate → reorder → simulate pipeline over several
//! random seeds in parallel and aggregate the three series every simulated
//! figure reports (Initial, Reordered, Lower bound).

use crate::bounds::theorem1_bounds;
use crate::ffnn::graph::Ffnn;
use crate::ffnn::topo::{two_optimal_order, ConnOrder};
use crate::memory::PolicyKind;
use crate::reorder::annealing::{reorder, AnnealConfig};
use crate::sim::simulate;
use crate::util::rng::Pcg64;
use crate::util::threadpool::par_map;

/// Per-seed outcome of one Connection-Reordering experiment.
#[derive(Clone, Debug)]
pub struct CrOutcome {
    pub initial_ios: u64,
    pub reordered_ios: u64,
    pub lower_bound: u64,
    pub upper_bound: u64,
    pub sa_secs: f64,
}

/// Configuration for a CR experiment point.
#[derive(Clone, Copy, Debug)]
pub struct CrConfig {
    pub m: usize,
    pub policy: PolicyKind,
    pub iters: u64,
    pub n_seeds: usize,
    pub workers: usize,
    pub base_seed: u64,
}

impl CrConfig {
    pub fn new(m: usize, iters: u64, n_seeds: usize) -> CrConfig {
        CrConfig {
            m,
            policy: PolicyKind::Min,
            iters,
            n_seeds,
            workers: workers_default(),
            base_seed: 0xF16,
        }
    }
}

/// Default worker count: physical parallelism minus headroom.
pub fn workers_default() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(2).max(1))
        .unwrap_or(4)
}

/// The iteration budget is specified *at the paper's baseline scale*
/// (W ≈ 75k connections) and rescaled per network so every point costs
/// roughly the same CPU: an SA evaluation is O(W), so `iters_eff =
/// iters · 75k / W`, clamped to [500, 4·iters]. EXPERIMENTS.md records
/// this scaling next to the paper's fixed T = 10⁶.
const BASELINE_W: u64 = 75_000;

pub fn scaled_iters(iters: u64, w: usize) -> u64 {
    (iters.saturating_mul(BASELINE_W) / (w as u64).max(1)).clamp(500, iters.saturating_mul(4))
}

/// Run the CR pipeline for each seed (in parallel): generate a network
/// with `gen`, simulate the 2-optimal *initial* order, reorder, simulate
/// the result.
pub fn cr_point(gen: &(dyn Fn(&mut Pcg64) -> Ffnn + Sync), cfg: &CrConfig) -> Vec<CrOutcome> {
    let seeds: Vec<u64> = (0..cfg.n_seeds as u64)
        .map(|i| cfg.base_seed.wrapping_add(i * 7919))
        .collect();
    par_map(cfg.workers, &seeds, |&seed| {
        let mut rng = Pcg64::seed_from(seed);
        let net = gen(&mut rng);
        run_cr_once(&net, cfg, seed)
    })
}

/// Single-network CR run (used by fig6/fig8 where the network is fixed
/// per density but policies vary).
pub fn run_cr_once(net: &Ffnn, cfg: &CrConfig, seed: u64) -> CrOutcome {
    let initial = two_optimal_order(net);
    let bounds = theorem1_bounds(net);
    let initial_ios = simulate(net, &initial, cfg.m, cfg.policy).total();
    let iters = scaled_iters(cfg.iters, net.n_conns());
    let mut acfg = AnnealConfig::new(cfg.m, cfg.policy, iters);
    acfg.seed = seed ^ 0xA11CE;
    let (_, rep) = reorder(net, &initial, &acfg);
    CrOutcome {
        initial_ios,
        reordered_ios: rep.final_ios,
        lower_bound: bounds.total_lower,
        upper_bound: bounds.total_upper,
        sa_secs: rep.elapsed_secs,
    }
}

/// Reorder returning the trace, for Fig. 4.
pub fn cr_trace(
    net: &Ffnn,
    initial: &ConnOrder,
    m: usize,
    policy: PolicyKind,
    iters: u64,
    trace_every: u64,
    seed: u64,
) -> Vec<(u64, u64)> {
    let mut cfg = AnnealConfig::new(m, policy, iters);
    cfg.trace_every = trace_every;
    cfg.seed = seed;
    let (_, rep) = reorder(net, initial, &cfg);
    rep.trace
}

/// Extract the per-seed series as f64 vectors (for `Report::record_sample`).
pub fn series(outcomes: &[CrOutcome]) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let ini = outcomes.iter().map(|o| o.initial_ios as f64).collect();
    let reo = outcomes.iter().map(|o| o.reordered_ios as f64).collect();
    let low = outcomes.iter().map(|o| o.lower_bound as f64).collect();
    (ini, reo, low)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_mlp, MlpSpec};

    #[test]
    fn cr_point_runs_all_seeds() {
        let cfg = CrConfig {
            m: 12,
            policy: PolicyKind::Min,
            iters: 200,
            n_seeds: 3,
            workers: 3,
            base_seed: 1,
        };
        let gen = |rng: &mut Pcg64| random_mlp(&MlpSpec::new(3, 16, 0.3), rng);
        let outs = cr_point(&gen, &cfg);
        assert_eq!(outs.len(), 3);
        for o in &outs {
            assert!(o.reordered_ios <= o.initial_ios);
            assert!(o.lower_bound <= o.reordered_ios);
            assert!(o.initial_ios <= o.upper_bound);
        }
        let (ini, reo, low) = series(&outs);
        assert_eq!((ini.len(), reo.len(), low.len()), (3, 3, 3));
    }
}
