//! Terminal ASCII plots: renders a [`Report`](super::harness::Report)'s
//! series as a simple scatter/line chart so `make figures` gives a visual
//! check of each reproduced paper figure without any plotting dependency.

use super::harness::Report;

const GLYPHS: &[char] = &['o', 'x', '+', '*', '#', '@', '%', '&'];

/// Render the report as an ASCII chart (`height` rows, `width` cols).
/// X positions are the distinct x-labels in insertion order (categorical,
/// matching the paper's swept parameters); Y is linear or log10.
pub fn ascii_chart(report: &Report, width: usize, height: usize, log_y: bool) -> String {
    let mut xs: Vec<&str> = Vec::new();
    let mut series: Vec<&str> = Vec::new();
    for p in &report.points {
        if !xs.contains(&p.x.as_str()) {
            xs.push(&p.x);
        }
        if !series.contains(&p.series.as_str()) {
            series.push(&p.series);
        }
    }
    if xs.is_empty() {
        return "(no data)\n".to_string();
    }
    let ys: Vec<f64> = report
        .points
        .iter()
        .map(|p| if log_y { p.value.max(1e-12).log10() } else { p.value })
        .collect();
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &y in &ys {
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let w = width.max(xs.len() * 2 + 2);
    let h = height.max(5);
    let mut grid = vec![vec![' '; w]; h];

    for p in &report.points {
        let xi = xs.iter().position(|x| *x == p.x).unwrap();
        let si = series.iter().position(|s| *s == p.series).unwrap();
        let y = if log_y { p.value.max(1e-12).log10() } else { p.value };
        let col = if xs.len() == 1 {
            w / 2
        } else {
            xi * (w - 1) / (xs.len() - 1)
        };
        let row_f = (y - ymin) / (ymax - ymin);
        let row = h - 1 - ((row_f * (h - 1) as f64).round() as usize).min(h - 1);
        grid[row][col] = GLYPHS[si % GLYPHS.len()];
    }

    let ylab = |v: f64| -> String {
        let v = if log_y { 10f64.powf(v) } else { v };
        if v.abs() >= 1e6 {
            format!("{:.1}M", v / 1e6)
        } else if v.abs() >= 1e3 {
            format!("{:.1}k", v / 1e3)
        } else {
            format!("{v:.1}")
        }
    };

    let mut out = format!("{} — {}\n", report.id, report.title);
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            ylab(ymax)
        } else if i == h - 1 {
            ylab(ymin)
        } else {
            String::new()
        };
        out.push_str(&format!("{label:>10} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>10} +{}\n", "", "-".repeat(w)));
    // X labels: first and last.
    out.push_str(&format!(
        "{:>12}{}{}\n",
        xs[0],
        " ".repeat(w.saturating_sub(xs[0].len() + xs[xs.len() - 1].len())),
        xs[xs.len() - 1]
    ));
    out.push_str("  legend: ");
    for (i, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", GLYPHS[i % GLYPHS.len()], s));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::Report;

    fn sample_report() -> Report {
        let mut r = Report::new("p", "plot test");
        for (i, x) in ["a", "b", "c"].iter().enumerate() {
            r.record_exact(x, "s1", (i + 1) as f64 * 10.0, "u");
            r.record_exact(x, "s2", (i + 1) as f64 * 20.0, "u");
        }
        r
    }

    #[test]
    fn chart_contains_glyphs_and_legend() {
        let c = ascii_chart(&sample_report(), 40, 10, false);
        assert!(c.contains('o') && c.contains('x'));
        assert!(c.contains("legend"));
        assert!(c.contains("s1") && c.contains("s2"));
    }

    #[test]
    fn log_scale_runs() {
        let mut r = Report::new("p2", "log");
        r.record_exact("a", "s", 10.0, "u");
        r.record_exact("b", "s", 100000.0, "u");
        let c = ascii_chart(&r, 30, 8, true);
        assert!(c.contains("100.0k") || c.contains("0.1M"));
    }

    #[test]
    fn empty_report_safe() {
        let r = Report::new("e", "empty");
        assert_eq!(ascii_chart(&r, 20, 5, false), "(no data)\n");
    }

    #[test]
    fn single_point_safe() {
        let mut r = Report::new("s", "single");
        r.record_exact("only", "s", 5.0, "u");
        let c = ascii_chart(&r, 20, 5, false);
        assert!(c.contains('o'));
    }
}
