//! Benchmark infrastructure: a micro-benchmark harness (criterion is not
//! available offline), result recording to `results/*.json`, and ASCII
//! plotting for terminal-rendered figures.

pub mod figures;
pub mod harness;
pub mod plot;
