//! Micro-benchmark harness used by every `rust/benches/*.rs` binary.
//!
//! Methodology follows the paper (§VI): each configuration is run
//! `reps` times (default 10) after warmup, outliers are flagged with
//! Tukey's method, and the reported statistic is the median with a 95%
//! nonparametric confidence interval. Results accumulate into a
//! [`Report`] that prints a fixed-width table (one row per configuration,
//! matching the paper's figure series) and serializes to
//! `results/<id>.json` for archival and re-plotting.

use crate::util::json::Json;
use crate::util::timing::{measure, tukey_filter, Summary};
use std::path::{Path, PathBuf};

/// One measured (or counted) series point.
#[derive(Clone, Debug)]
pub struct Point {
    /// X-axis label, e.g. "density=0.10" or "M=100".
    pub x: String,
    /// Series name, e.g. "Initial", "Reordered", "Lower bound".
    pub series: String,
    /// Central value (median for timings, exact count for simulations).
    pub value: f64,
    /// CI bounds (equal to `value` for exact counts).
    pub lo: f64,
    pub hi: f64,
    /// Unit, e.g. "I/Os", "ms".
    pub unit: String,
    /// Outliers removed by Tukey filtering (timings only).
    pub outliers_removed: usize,
}

/// Accumulates points for one experiment (one paper figure).
#[derive(Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub points: Vec<Point>,
    pub meta: Json,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            points: Vec::new(),
            meta: Json::obj(),
        }
    }

    pub fn set_meta(&mut self, key: &str, value: impl Into<Json>) {
        let meta = std::mem::replace(&mut self.meta, Json::Null);
        self.meta = meta.set(key, value);
    }

    /// Record an exact (deterministic) count, e.g. simulated I/Os.
    pub fn record_exact(&mut self, x: &str, series: &str, value: f64, unit: &str) {
        self.points.push(Point {
            x: x.to_string(),
            series: series.to_string(),
            value,
            lo: value,
            hi: value,
            unit: unit.to_string(),
            outliers_removed: 0,
        });
    }

    /// Record a sample of repeated measurements (e.g. wall-clock times, or
    /// per-seed I/O counts): stores median + 95% CI after Tukey filtering.
    pub fn record_sample(&mut self, x: &str, series: &str, samples: &[f64], unit: &str) {
        let (kept, dropped) = tukey_filter(samples);
        let s = Summary::of(&kept);
        self.points.push(Point {
            x: x.to_string(),
            series: series.to_string(),
            value: s.median,
            lo: s.ci_lo,
            hi: s.ci_hi,
            unit: unit.to_string(),
            outliers_removed: dropped.len(),
        });
    }

    /// Record a throughput series from repeated timings: each sample
    /// becomes `work / time`, e.g. batch rows per second when `work` is
    /// the batch size (used by the parallel-execution benches).
    pub fn record_rate(
        &mut self,
        x: &str,
        series: &str,
        work: f64,
        times_secs: &[f64],
        unit: &str,
    ) {
        let rates: Vec<f64> = times_secs.iter().map(|&t| work / t.max(1e-12)).collect();
        self.record_sample(x, series, &rates, unit);
    }

    /// Time a closure `reps` times (after `warmup`) and record the median.
    pub fn record_timing<T>(
        &mut self,
        x: &str,
        series: &str,
        warmup: usize,
        reps: usize,
        f: impl FnMut() -> T,
    ) {
        let times = measure(warmup, reps, f);
        let ms: Vec<f64> = times.iter().map(|t| t * 1e3).collect();
        self.record_sample(x, series, &ms, "ms");
    }

    /// Fixed-width table, grouped by x, one column per series.
    pub fn table(&self) -> String {
        let mut xs: Vec<&str> = Vec::new();
        let mut series: Vec<&str> = Vec::new();
        for p in &self.points {
            if !xs.contains(&p.x.as_str()) {
                xs.push(&p.x);
            }
            if !series.contains(&p.series.as_str()) {
                series.push(&p.series);
            }
        }
        let unit = self
            .points
            .first()
            .map(|p| p.unit.clone())
            .unwrap_or_default();
        let mut out = format!("== {} — {} [{unit}] ==\n", self.id, self.title);
        let xw = xs.iter().map(|x| x.len()).max().unwrap_or(1).max(8);
        out.push_str(&format!("{:<xw$}", "x"));
        for s in &series {
            out.push_str(&format!(" | {s:>24}"));
        }
        out.push('\n');
        out.push_str(&"-".repeat(xw + series.len() * 27));
        out.push('\n');
        for x in &xs {
            out.push_str(&format!("{x:<xw$}"));
            for s in &series {
                let cell = self
                    .points
                    .iter()
                    .find(|p| p.x == *x && p.series == *s)
                    .map(|p| {
                        if p.lo == p.value && p.hi == p.value {
                            format!("{}", fmt_num(p.value))
                        } else {
                            format!("{} [{},{}]", fmt_num(p.value), fmt_num(p.lo), fmt_num(p.hi))
                        }
                    })
                    .unwrap_or_else(|| "-".to_string());
                out.push_str(&format!(" | {cell:>24}"));
            }
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .points
            .iter()
            .map(|p| {
                Json::obj()
                    .set("x", p.x.as_str())
                    .set("series", p.series.as_str())
                    .set("value", p.value)
                    .set("lo", p.lo)
                    .set("hi", p.hi)
                    .set("unit", p.unit.as_str())
                    .set("outliers_removed", p.outliers_removed)
            })
            .collect();
        let j = Json::obj()
            .set("id", self.id.as_str())
            .set("title", self.title.as_str())
            .set("meta", self.meta.clone())
            .set("points", Json::Arr(points));
        if self.points.is_empty() {
            // A bench whose sweep selected zero configurations (feature
            // not supported on this CPU, filtered dimension, ...) still
            // publishes a valid report; the explicit marker separates
            // "ran and measured nothing" from a missing or truncated
            // file when tooling diffs the perf trajectory.
            j.set("skipped", true)
        } else {
            j
        }
    }

    /// Print table to stdout and save JSON under `results/<id>.json`;
    /// `perf_*` reports are additionally published to the repo root as
    /// `BENCH_PERF_<NAME>.json` (see [`perf_results_path`]) so the perf
    /// trajectory is visible without digging into `results/` — unless
    /// the report is marked as a `--quick` smoke run (`meta.quick`),
    /// whose non-representative numbers must not overwrite the tracked
    /// trajectory.
    pub fn finish(&self) {
        if self.points.is_empty() {
            println!(
                "== {} — {} == no configurations ran; writing skipped report",
                self.id, self.title
            );
        } else {
            println!("{}", self.table());
        }
        let quick = self
            .meta
            .get("quick")
            .and_then(Json::as_bool)
            .unwrap_or(false);
        let mut paths = vec![results_path(&self.id)];
        if !quick {
            paths.extend(perf_results_path(&self.id));
        }
        for path in paths {
            if let Err(e) = self.to_json().to_file(&path) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                println!("saved {}", path.display());
            }
        }
    }
}

/// Location for result JSON (respects `SPARSEFLOW_RESULTS_DIR`).
pub fn results_path(id: &str) -> PathBuf {
    let dir = std::env::var("SPARSEFLOW_RESULTS_DIR").unwrap_or_else(|_| "results".to_string());
    PathBuf::from(dir).join(format!("{id}.json"))
}

/// Repo-root location for a perf-series report: `perf_<name>` maps to
/// `<repo root>/BENCH_PERF_<NAME>.json` (directory overridable via
/// `SPARSEFLOW_PERF_DIR`); figure benches (`fig2`, `thm1`, ...) return
/// `None` and stay under `results/` only.
pub fn perf_results_path(id: &str) -> Option<PathBuf> {
    let name = id.strip_prefix("perf_")?;
    let dir = match std::env::var("SPARSEFLOW_PERF_DIR") {
        Ok(d) => PathBuf::from(d),
        Err(_) => {
            // CARGO_MANIFEST_DIR is the crate dir (`rust/`) on the build
            // machine; its parent is the repository root. When the
            // binary runs from a relocated checkout that path no longer
            // exists — fall back to `..`, which matches how cargo runs
            // benches (cwd = package root) the way `results_path`'s
            // relative `results/` does.
            match Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
                Some(root) if root.is_dir() => root.to_path_buf(),
                _ => PathBuf::from(".."),
            }
        }
    };
    Some(dir.join(format!("BENCH_PERF_{}.json", name.to_uppercase())))
}

fn fmt_num(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1e6 {
        format!("{:.3}M", v / 1e6)
    } else if a >= 1e4 {
        format!("{:.1}k", v / 1e3)
    } else if a >= 100.0 || v.fract() == 0.0 {
        format!("{v:.0}")
    } else if a >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_has_all_series() {
        let mut r = Report::new("t1", "test");
        r.record_exact("d=0.1", "Initial", 100.0, "I/Os");
        r.record_exact("d=0.1", "Reordered", 80.0, "I/Os");
        r.record_exact("d=0.2", "Initial", 200.0, "I/Os");
        let t = r.table();
        assert!(t.contains("Initial") && t.contains("Reordered"));
        assert!(t.contains("d=0.1") && t.contains("d=0.2"));
        assert!(t.contains(" - ") || t.contains("-"), "missing cell dash");
    }

    #[test]
    fn sample_recording_uses_median() {
        let mut r = Report::new("t2", "test");
        r.record_sample("x", "s", &[1.0, 2.0, 3.0, 4.0, 100.0], "ms");
        let p = &r.points[0];
        assert_eq!(p.outliers_removed, 1); // Tukey drops 100.0
        assert_eq!(p.value, 2.5);
    }

    #[test]
    fn rate_recording_inverts_times() {
        let mut r = Report::new("t4", "rate");
        r.record_rate("x", "s", 100.0, &[0.5, 0.25], "rows/s");
        let p = &r.points[0];
        assert_eq!(p.unit, "rows/s");
        // Samples 200 and 400 rows/s ⇒ median 300.
        assert!((p.value - 300.0).abs() < 1e-9, "median {}", p.value);
    }

    #[test]
    fn json_roundtrip() {
        let mut r = Report::new("t3", "test");
        r.set_meta("seed", 42u64);
        r.record_exact("a", "s", 5.0, "I/Os");
        let j = r.to_json();
        assert_eq!(j.get("id").unwrap().as_str(), Some("t3"));
        assert_eq!(j.path(&["meta", "seed"]).unwrap().as_u64(), Some(42));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn empty_report_carries_explicit_skipped_marker() {
        let r = Report::new("perf_x", "zero configs ran");
        let j = r.to_json();
        assert_eq!(j.get("skipped").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("points").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(j.get("id").unwrap().as_str(), Some("perf_x"), "report stays well-formed");

        let mut r = Report::new("perf_x", "one config ran");
        r.record_exact("a", "s", 1.0, "I/Os");
        assert_eq!(r.to_json().get("skipped"), None, "non-empty reports carry no marker");
    }

    #[test]
    fn perf_reports_publish_to_repo_root() {
        let p = perf_results_path("perf_fused").expect("perf ids publish");
        assert!(p.ends_with("BENCH_PERF_FUSED.json"), "{p:?}");
        assert_eq!(perf_results_path("fig2"), None, "figure benches stay in results/");
    }

    #[test]
    fn fmt_num_ranges() {
        assert_eq!(fmt_num(0.0), "0");
        assert_eq!(fmt_num(2_500_000.0), "2.500M");
        assert_eq!(fmt_num(25_000.0), "25.0k");
        assert_eq!(fmt_num(123.0), "123");
        assert_eq!(fmt_num(1.5), "1.50");
        assert_eq!(fmt_num(0.125), "0.1250");
    }
}
