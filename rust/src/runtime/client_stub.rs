//! Stub PJRT client, compiled when the `xla` feature is **off** (the
//! default: the `xla` crate is unavailable offline). Mirrors the public
//! API of `runtime::client` so code and tests compile unchanged; every
//! constructor returns an error, and the `runtime_e2e` tests skip
//! because no artifact manifest exists without the XLA toolchain.

use super::artifact::Manifest;
use super::pack::EllLayer;
use crate::exec::batch::BatchMatrix;
use crate::exec::Engine;
use std::path::{Path, PathBuf};

const UNAVAILABLE: &str = "sparseflow was built without the `xla` feature; the PJRT runtime \
     requires the vendored `xla` crate (see README: Runtime backends)";

/// Placeholder for the PJRT CPU runtime.
pub struct Runtime {
    _priv: (),
}

impl Runtime {
    /// Always fails in the stub build.
    pub fn cpu() -> anyhow::Result<Runtime> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn load_hlo_text(&self, _path: &Path) -> anyhow::Result<XlaExecutable> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    pub fn load_artifact(
        &self,
        _manifest: &Manifest,
        _name: &str,
    ) -> anyhow::Result<XlaExecutable> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }
}

/// Placeholder for a compiled HLO executable.
pub struct XlaExecutable {
    _priv: (),
}

impl XlaExecutable {
    pub fn run(&self, _inputs: &[Literal]) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }
}

/// Opaque placeholder for `xla::Literal`.
pub struct Literal {
    _priv: (),
}

pub fn literal_f32(_data: &[f32], _shape: &[usize]) -> anyhow::Result<Literal> {
    Err(anyhow::anyhow!(UNAVAILABLE))
}

pub fn literal_i32(_data: &[i32], _shape: &[usize]) -> anyhow::Result<Literal> {
    Err(anyhow::anyhow!(UNAVAILABLE))
}

/// Placeholder engine; [`XlaEngine::from_ell`] always fails, so no value
/// of this type can ever be constructed in a stub build.
pub struct XlaEngine {
    n_in: usize,
    n_out: usize,
    batch: usize,
}

impl XlaEngine {
    pub fn from_ell(
        _artifacts_dir: PathBuf,
        _name: &str,
        _layers: Vec<EllLayer>,
    ) -> anyhow::Result<XlaEngine> {
        Err(anyhow::anyhow!(UNAVAILABLE))
    }

    pub fn artifact_batch(&self) -> usize {
        self.batch
    }
}

impl Engine for XlaEngine {
    fn infer(&self, _inputs: &BatchMatrix) -> BatchMatrix {
        unreachable!("stub XlaEngine cannot be constructed")
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn n_inputs(&self) -> usize {
        self.n_in
    }

    fn n_outputs(&self) -> usize {
        self.n_out
    }
}
