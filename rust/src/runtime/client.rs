//! The PJRT client wrapper: compile HLO-text artifacts once, execute many
//! times from the serving hot path. Adapted from the working reference in
//! /opt/xla-example/src/bin/load_hlo.rs.
//!
//! Thread model: the `xla` crate's handles are thread-confined (`Rc`
//! internals, raw C pointers), so [`Runtime`]/[`XlaExecutable`] are
//! single-threaded values. The serving path uses [`XlaEngine`], a
//! `Send + Sync` handle to a dedicated **service thread** that owns the
//! PJRT client, the compiled executable and the parameter literals, and
//! processes inference requests over channels.

use super::artifact::Manifest;
use super::pack::EllLayer;
use crate::exec::batch::BatchMatrix;
use crate::exec::Engine;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

/// A PJRT CPU runtime holding the client; executables are compiled from
/// HLO text files. Not `Send`: confine to one thread (see [`XlaEngine`]).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> anyhow::Result<XlaExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(XlaExecutable { exe })
    }

    /// Load an artifact by name through the manifest.
    pub fn load_artifact(&self, manifest: &Manifest, name: &str) -> anyhow::Result<XlaExecutable> {
        let meta = manifest.find(name)?;
        self.load_hlo_text(&manifest.hlo_path(meta))
    }
}

/// A compiled executable; `run` takes literals and unwraps the 1-tuple
/// output (artifacts are lowered with `return_tuple=True`).
pub struct XlaExecutable {
    exe: xla::PjRtLoadedExecutable,
}

impl XlaExecutable {
    /// Execute with the given input literals; returns the flat f32 data
    /// and the output dimensions.
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let shape = out.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok((data, dims))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {shape:?} != data len {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {shape:?} != data len {}", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("{e:?}"))
}

// ---------------------------------------------------------------------
// Service-thread engine
// ---------------------------------------------------------------------

enum ServiceMsg {
    Infer {
        inputs: BatchMatrix,
        reply: mpsc::Sender<anyhow::Result<BatchMatrix>>,
    },
    Shutdown,
}

/// An [`Engine`] executing an ELL-MLP artifact on PJRT through a
/// dedicated service thread. The artifact's batch size is fixed at AOT
/// time; smaller request batches are padded and sliced.
pub struct XlaEngine {
    tx: Mutex<mpsc::Sender<ServiceMsg>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
    n_in: usize,
    n_out: usize,
    batch: usize,
}

impl XlaEngine {
    /// Spawn the service thread: it loads the manifest from
    /// `artifacts_dir`, compiles artifact `name`, validates the packed
    /// `layers` against it and prepares the parameter literals.
    pub fn from_ell(
        artifacts_dir: PathBuf,
        name: &str,
        layers: Vec<EllLayer>,
    ) -> anyhow::Result<XlaEngine> {
        // Validate shapes up front (cheap, no xla involvement).
        let manifest = Manifest::load(&artifacts_dir)?;
        let meta = manifest.find(name)?.clone();
        let shapes = meta.ell_layer_shapes()?;
        anyhow::ensure!(
            shapes.len() == layers.len(),
            "artifact has {} layers, packed {}",
            shapes.len(),
            layers.len()
        );
        for (li, (layer, &(n_out, k, n_in))) in layers.iter().zip(&shapes).enumerate() {
            anyhow::ensure!(
                (layer.n_out, layer.k, layer.n_in) == (n_out, k, n_in),
                "layer {li}: packed ({}, {}, {}) != artifact ({n_out}, {k}, {n_in})",
                layer.n_out,
                layer.k,
                layer.n_in
            );
        }
        let n_in = shapes[0].2;
        let n_out = shapes.last().unwrap().0;
        let batch = meta.batch;
        let artifact_name = name.to_string();

        let (tx, rx) = mpsc::channel::<ServiceMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name(format!("xla-service-{artifact_name}"))
            .spawn(move || {
                // Everything xla-related lives and dies on this thread.
                let setup = (|| -> anyhow::Result<(XlaExecutable, Vec<xla::Literal>)> {
                    let runtime = Runtime::cpu()?;
                    let manifest = Manifest::load(&artifacts_dir)?;
                    let meta = manifest.find(&artifact_name)?;
                    let exe = runtime.load_hlo_text(&manifest.hlo_path(meta))?;
                    let mut params = Vec::with_capacity(layers.len() * 3);
                    for layer in &layers {
                        params.push(literal_f32(&layer.weights, &[layer.n_out, layer.k])?);
                        params.push(literal_i32(&layer.indices, &[layer.n_out, layer.k])?);
                        params.push(literal_f32(&layer.bias, &[layer.n_out])?);
                    }
                    Ok((exe, params))
                })();
                let (exe, params) = match setup {
                    Ok(v) => {
                        let _ = ready_tx.send(Ok(()));
                        v
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };

                while let Ok(msg) = rx.recv() {
                    match msg {
                        ServiceMsg::Shutdown => break,
                        ServiceMsg::Infer { inputs, reply } => {
                            let out = infer_once(&exe, &params, &inputs, n_in, n_out, batch);
                            let _ = reply.send(out);
                        }
                    }
                }
            })
            .map_err(|e| anyhow::anyhow!("spawn xla service: {e}"))?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("xla service thread died during setup"))??;

        Ok(XlaEngine {
            tx: Mutex::new(tx),
            join: Mutex::new(Some(join)),
            n_in,
            n_out,
            batch,
        })
    }

    /// Artifact batch size (requests are padded up to this).
    pub fn artifact_batch(&self) -> usize {
        self.batch
    }
}

fn infer_once(
    exe: &XlaExecutable,
    params: &[xla::Literal],
    inputs: &BatchMatrix,
    n_in: usize,
    n_out: usize,
    batch: usize,
) -> anyhow::Result<BatchMatrix> {
    anyhow::ensure!(inputs.rows() == n_in, "input rows {} != {n_in}", inputs.rows());
    let req_batch = inputs.batch();
    anyhow::ensure!(
        req_batch <= batch,
        "request batch {req_batch} exceeds artifact batch {batch}"
    );
    let mut padded = vec![0.0f32; n_in * batch];
    for r in 0..n_in {
        padded[r * batch..r * batch + req_batch].copy_from_slice(inputs.row(r));
    }
    let x = literal_f32(&padded, &[n_in, batch])?;

    // `execute` borrows literals; pass params + x in artifact order.
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.push(&x);
    // The xla crate's execute takes `&[Literal]` via Borrow; build owned
    // slice references through its generic parameter.
    let (data, dims) = run_with_refs(exe, &args)?;
    anyhow::ensure!(
        dims == vec![n_out, batch],
        "unexpected output dims {dims:?}, want [{n_out}, {batch}]"
    );
    let mut out = BatchMatrix::zeros(n_out, req_batch);
    for r in 0..n_out {
        out.row_mut(r)
            .copy_from_slice(&data[r * batch..r * batch + req_batch]);
    }
    Ok(out)
}

fn run_with_refs(
    exe: &XlaExecutable,
    args: &[&xla::Literal],
) -> anyhow::Result<(Vec<f32>, Vec<usize>)> {
    let result = exe
        .exe
        .execute::<&xla::Literal>(args)
        .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
    let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let shape = out.array_shape().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    Ok((data, dims))
}

impl Drop for XlaEngine {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(ServiceMsg::Shutdown);
        }
        if let Ok(mut j) = self.join.lock() {
            if let Some(h) = j.take() {
                let _ = h.join();
            }
        }
    }
}

impl Engine for XlaEngine {
    fn infer(&self, inputs: &BatchMatrix) -> BatchMatrix {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("xla engine sender");
            tx.send(ServiceMsg::Infer {
                inputs: inputs.clone(),
                reply: reply_tx,
            })
            .expect("xla service alive");
        }
        reply_rx
            .recv()
            .expect("xla service reply")
            .expect("artifact execution")
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn n_inputs(&self) -> usize {
        self.n_in
    }

    fn n_outputs(&self) -> usize {
        self.n_out
    }
}
