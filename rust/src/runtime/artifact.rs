//! Model artifacts.
//!
//! Two formats live here:
//!
//! * the JSON `artifacts/manifest.json` written by
//!   `python/compile/aot.py`, describing each lowered HLO module and its
//!   expected input shapes/dtypes so the Rust loader can validate
//!   literals before execution, and
//! * `sparseflow-bin-v1` (`.sfb`): a checksummed, versioned **zero-copy**
//!   binary model format whose 64-byte-aligned sections hold the exact
//!   structure-of-arrays pools the fused/tiled/quant engines execute, so
//!   loading is validate-header + borrow-slices — no parsing and no
//!   per-pool copies on the mmap path (see [`BinArtifact`]).

use crate::exec::fused::{FusedPools, FusedProgram};
use crate::exec::quant::{
    QuantFusedPools, QuantFusedProgram, QuantGroup, QuantPools, QuantStreamProgram,
    QuantTiledProgram, GROUP,
};
use crate::exec::stream::StreamProgram;
use crate::exec::tiled::TiledProgram;
use crate::ffnn::graph::Ffnn;
use crate::ffnn::topo::ConnOrder;
use crate::runtime::mmap::{Mapping, Pool, SECTION_ALIGN};
use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Input tensor descriptor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    /// "float32" | "int32" (the only dtypes the artifacts use).
    pub dtype: String,
}

impl TensorSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// "ell_mlp" | "dense_mlp".
    pub kind: String,
    /// Batch size baked into the module.
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// For ELL artifacts: the (n_out, K, n_in) triple of each layer,
    /// recovered from the weights/indices/bias input shapes.
    pub fn ell_layer_shapes(&self) -> anyhow::Result<Vec<(usize, usize, usize)>> {
        anyhow::ensure!(self.kind == "ell_mlp", "not an ell_mlp artifact");
        anyhow::ensure!(self.inputs.len() % 3 == 1, "inputs must be 3·L + 1");
        let n_layers = self.inputs.len() / 3;
        let mut shapes = Vec::with_capacity(n_layers);
        let x_shape = &self.inputs.last().unwrap().shape;
        let mut n_in = x_shape[0];
        for li in 0..n_layers {
            let w = &self.inputs[3 * li];
            anyhow::ensure!(w.shape.len() == 2, "weights must be 2-D");
            let (n_out, k) = (w.shape[0], w.shape[1]);
            shapes.push((n_out, k, n_in));
            n_in = n_out;
        }
        Ok(shapes)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let j = Json::from_file(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            j.get("format").and_then(Json::as_str) == Some("sparseflow-artifacts-v1"),
            "unknown manifest format in {}",
            path.display()
        );
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
        {
            let get_str = |k: &str| -> anyhow::Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("artifact missing inputs"))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow::anyhow!("input missing shape"))?
                        .iter()
                        .map(|d| {
                            d.as_u64()
                                .map(|v| v as usize)
                                .ok_or_else(|| anyhow::anyhow!("bad dim"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?;
                    let dtype = i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    Ok(TensorSpec { shape, dtype })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                file: get_str("file")?,
                kind: get_str("kind")?,
                batch: a.get("batch").and_then(Json::as_u64).unwrap_or(0) as usize,
                inputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("no artifact named {name:?} (have: {:?})",
                self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Default artifacts directory (`SPARSEFLOW_ARTIFACTS` or `artifacts/`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SPARSEFLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

// ---------------------------------------------------------------------------
// sparseflow-bin-v1 — zero-copy binary model artifacts.
//
// Layout (all integers little-endian; the format is LE-only and loads
// reject foreign-endian files via the endian tag):
//
//   header (64 B):
//     0..8    magic "SFLOWBIN"
//     8..12   format version (1)
//     12..16  abi version (1)
//     16..20  endian tag: 0x01020304 as written by the producing host
//     20..24  n_sections
//     24..32  file length (u64)
//     32..36  CRC-32 of the section table
//     36..60  reserved (zero)
//     60..64  CRC-32 of header bytes 0..60
//   section table (n_sections × 32 B entries):
//     kind u32, dtype u32, offset u64, len u64, crc u32, reserved u32
//   sections: each starts at a 64-byte-aligned offset. Alignment gap
//   bytes are zero and are NOT checksummed.
//
// Unknown section kinds are ignored (forward compatibility); duplicate
// kinds are rejected.
// ---------------------------------------------------------------------------

pub const SFB_MAGIC: [u8; 8] = *b"SFLOWBIN";
pub const SFB_FORMAT_VERSION: u32 = 1;
pub const SFB_ABI_VERSION: u32 = 1;
pub const SFB_ENDIAN_TAG: u32 = 0x0102_0304;
pub const SFB_HEADER_LEN: usize = 64;
pub const SFB_ENTRY_LEN: usize = 32;

/// Section kinds. 1..16 model-level, 16..32 fused pools, 32..35 the
/// quant interpreter stream, 35.. the quant-fused weight pools (the
/// idx/flag/ctrl pools of the quant-fused program are the `SEC_FUSED_*`
/// sections — shared with the f32 compilation path by construction).
pub const SEC_META: u32 = 1;
pub const SEC_BIASES: u32 = 2;
pub const SEC_INPUT_IDS: u32 = 3;
pub const SEC_OUTPUT_IDS: u32 = 4;
pub const SEC_HIDDEN_SOURCES: u32 = 5;
pub const SEC_LAYER_OF: u32 = 6;
pub const SEC_FUSED_CTRL: u32 = 16;
pub const SEC_FUSED_PIVOTS: u32 = 17;
pub const SEC_FUSED_BOUNDS: u32 = 18;
pub const SEC_FUSED_IDX: u32 = 19;
pub const SEC_FUSED_WEIGHTS: u32 = 20;
pub const SEC_FUSED_FLAGS: u32 = 21;
pub const SEC_QUANT_CTRL: u32 = 32;
pub const SEC_QUANT_QWEIGHTS: u32 = 33;
pub const SEC_QUANT_GROUPS: u32 = 34;
pub const SEC_QFUSED_QWEIGHTS: u32 = 35;
pub const SEC_QFUSED_GROUPS: u32 = 36;
pub const SEC_QFUSED_GROUP_BOUNDS: u32 = 37;

/// Element dtypes (`SEC_QUANT_GROUPS` is f32 pairs: scale, zero_point).
pub const DT_U8: u32 = 0;
pub const DT_I8: u32 = 1;
pub const DT_U32: u32 = 2;
pub const DT_F32: u32 = 3;
pub const DT_U64: u32 = 4;

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

fn align_up(off: usize) -> usize {
    off.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

fn kind_name(kind: u32) -> &'static str {
    match kind {
        SEC_META => "meta",
        SEC_BIASES => "biases",
        SEC_INPUT_IDS => "input_ids",
        SEC_OUTPUT_IDS => "output_ids",
        SEC_HIDDEN_SOURCES => "hidden_sources",
        SEC_LAYER_OF => "layer_of",
        SEC_FUSED_CTRL => "fused_ctrl",
        SEC_FUSED_PIVOTS => "fused_pivots",
        SEC_FUSED_BOUNDS => "fused_bounds",
        SEC_FUSED_IDX => "fused_idx",
        SEC_FUSED_WEIGHTS => "fused_weights",
        SEC_FUSED_FLAGS => "fused_flags",
        SEC_QUANT_CTRL => "quant_ctrl",
        SEC_QUANT_QWEIGHTS => "quant_qweights",
        SEC_QUANT_GROUPS => "quant_groups",
        SEC_QFUSED_QWEIGHTS => "qfused_qweights",
        SEC_QFUSED_GROUPS => "qfused_groups",
        SEC_QFUSED_GROUP_BOUNDS => "qfused_group_bounds",
        _ => "unknown",
    }
}

fn dtype_name(dtype: u32) -> &'static str {
    match dtype {
        DT_U8 => "u8",
        DT_I8 => "i8",
        DT_U32 => "u32",
        DT_F32 => "f32",
        DT_U64 => "u64",
        _ => "?",
    }
}

/// Expected dtype per known kind (None for unknown kinds).
fn known_dtype(kind: u32) -> Option<u32> {
    match kind {
        SEC_META => Some(DT_U64),
        SEC_BIASES | SEC_FUSED_WEIGHTS | SEC_QUANT_GROUPS | SEC_QFUSED_GROUPS => Some(DT_F32),
        SEC_INPUT_IDS | SEC_OUTPUT_IDS | SEC_HIDDEN_SOURCES | SEC_LAYER_OF => Some(DT_U32),
        SEC_FUSED_PIVOTS | SEC_FUSED_BOUNDS | SEC_FUSED_IDX | SEC_QFUSED_GROUP_BOUNDS => {
            Some(DT_U32)
        }
        SEC_FUSED_CTRL | SEC_FUSED_FLAGS | SEC_QUANT_CTRL => Some(DT_U8),
        SEC_QUANT_QWEIGHTS | SEC_QFUSED_QWEIGHTS => Some(DT_I8),
        _ => None,
    }
}

fn le_bytes_u32(vals: &[u32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_bytes_f32(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_bytes_groups(groups: &[QuantGroup]) -> Vec<u8> {
    let mut out = Vec::with_capacity(groups.len() * 8);
    for g in groups {
        out.extend_from_slice(&g.scale.to_le_bytes());
        out.extend_from_slice(&g.zero_point.to_le_bytes());
    }
    out
}

/// One entry of the section table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SectionInfo {
    pub kind: u32,
    pub dtype: u32,
    pub offset: u64,
    pub len: u64,
    pub crc: u32,
}

/// Serialize a network (with its I/O-optimal order) into a
/// `sparseflow-bin-v1` buffer: compile once here so every future load
/// is validate + borrow.
pub fn build_model_artifact(net: &Ffnn, order: &ConnOrder) -> Vec<u8> {
    let stream = StreamProgram::compile(net, order);
    let fused = FusedProgram::from_program(&stream);
    let quant = QuantStreamProgram::from_program(&stream);
    let qfused = QuantFusedProgram::from_quant(&quant);
    // Per-group element boundaries into the quant-fused weight pool:
    // [0, GROUP, 2·GROUP, …, n_ops]. Redundant with the compiled-in
    // GROUP, but stored (and revalidated on load) so the group layout
    // is explicit in the file rather than implied by the reader.
    let mut qf_group_bounds: Vec<u32> = (0..qfused.groups().len())
        .map(|g| (g * GROUP) as u32)
        .collect();
    qf_group_bounds.push(qfused.quantized_weights().len() as u32);

    let mut meta = Vec::with_capacity(24);
    for v in [net.n_neurons() as u64, net.n_conns() as u64, GROUP as u64] {
        meta.extend_from_slice(&v.to_le_bytes());
    }
    let mut secs: Vec<(u32, u32, Vec<u8>)> = vec![
        (SEC_META, DT_U64, meta),
        (SEC_BIASES, DT_F32, le_bytes_f32(fused.biases())),
        (SEC_INPUT_IDS, DT_U32, le_bytes_u32(fused.input_ids())),
        (SEC_OUTPUT_IDS, DT_U32, le_bytes_u32(fused.output_ids())),
        (SEC_HIDDEN_SOURCES, DT_U32, le_bytes_u32(fused.hidden_sources())),
        (SEC_FUSED_CTRL, DT_U8, fused.ctrl().to_vec()),
        (SEC_FUSED_PIVOTS, DT_U32, le_bytes_u32(fused.pivots())),
        (SEC_FUSED_BOUNDS, DT_U32, le_bytes_u32(fused.bounds())),
        (SEC_FUSED_IDX, DT_U32, le_bytes_u32(fused.idx())),
        (SEC_FUSED_WEIGHTS, DT_F32, le_bytes_f32(fused.weights())),
        (SEC_FUSED_FLAGS, DT_U8, fused.flags().to_vec()),
        (SEC_QUANT_CTRL, DT_U8, quant.ctrl_bytes().to_vec()),
        (
            SEC_QUANT_QWEIGHTS,
            DT_I8,
            quant.quantized_weights().iter().map(|&v| v as u8).collect(),
        ),
        (SEC_QUANT_GROUPS, DT_F32, le_bytes_groups(quant.groups())),
        (
            SEC_QFUSED_QWEIGHTS,
            DT_I8,
            qfused.quantized_weights().iter().map(|&v| v as u8).collect(),
        ),
        (SEC_QFUSED_GROUPS, DT_F32, le_bytes_groups(qfused.groups())),
        (SEC_QFUSED_GROUP_BOUNDS, DT_U32, le_bytes_u32(&qf_group_bounds)),
    ];
    if let Some(layers) = net.layer_of() {
        secs.push((SEC_LAYER_OF, DT_U32, le_bytes_u32(layers)));
    }

    let n = secs.len();
    let table_len = n * SFB_ENTRY_LEN;
    let mut off = align_up(SFB_HEADER_LEN + table_len);
    let mut infos = Vec::with_capacity(n);
    for (kind, dtype, payload) in &secs {
        infos.push(SectionInfo {
            kind: *kind,
            dtype: *dtype,
            offset: off as u64,
            len: payload.len() as u64,
            crc: crc32(payload),
        });
        off = align_up(off + payload.len());
    }
    let file_len = infos
        .last()
        .map(|s| (s.offset + s.len) as usize)
        .unwrap_or(SFB_HEADER_LEN + table_len);

    let mut table = Vec::with_capacity(table_len);
    for s in &infos {
        table.extend_from_slice(&s.kind.to_le_bytes());
        table.extend_from_slice(&s.dtype.to_le_bytes());
        table.extend_from_slice(&s.offset.to_le_bytes());
        table.extend_from_slice(&s.len.to_le_bytes());
        table.extend_from_slice(&s.crc.to_le_bytes());
        table.extend_from_slice(&0u32.to_le_bytes());
    }

    let mut buf = vec![0u8; file_len];
    buf[SFB_HEADER_LEN..SFB_HEADER_LEN + table_len].copy_from_slice(&table);
    for (s, (_, _, payload)) in infos.iter().zip(&secs) {
        let o = s.offset as usize;
        buf[o..o + payload.len()].copy_from_slice(payload);
    }
    buf[0..8].copy_from_slice(&SFB_MAGIC);
    buf[8..12].copy_from_slice(&SFB_FORMAT_VERSION.to_le_bytes());
    buf[12..16].copy_from_slice(&SFB_ABI_VERSION.to_le_bytes());
    buf[16..20].copy_from_slice(&SFB_ENDIAN_TAG.to_ne_bytes());
    buf[20..24].copy_from_slice(&(n as u32).to_le_bytes());
    buf[24..32].copy_from_slice(&(file_len as u64).to_le_bytes());
    buf[32..36].copy_from_slice(&crc32(&table).to_le_bytes());
    let hc = crc32(&buf[0..60]);
    buf[60..64].copy_from_slice(&hc.to_le_bytes());
    buf
}

/// Build and write a `.sfb` artifact for `net` at `path`.
pub fn write_model_artifact(net: &Ffnn, order: &ConnOrder, path: &Path) -> anyhow::Result<()> {
    let buf = build_model_artifact(net, order);
    std::fs::write(path, &buf)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))?;
    Ok(())
}

/// A validated, loaded `sparseflow-bin-v1` artifact. Holds the backing
/// [`Mapping`]; program constructors borrow section slices out of it
/// (zero per-pool copies on the mmap path).
#[derive(Clone, Debug)]
pub struct BinArtifact {
    map: Arc<Mapping>,
    sections: Vec<SectionInfo>,
    n_neurons: usize,
    n_conns: usize,
    group_size: usize,
}

fn read_u32(bytes: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap())
}

fn read_u64(bytes: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(bytes[at..at + 8].try_into().unwrap())
}

impl BinArtifact {
    /// Memory-map `path` and validate it (header, table, per-section
    /// checksums). Falls back to a heap read where mmap is unavailable.
    pub fn load(path: &Path) -> anyhow::Result<BinArtifact> {
        let map =
            Mapping::open(path).map_err(|e| anyhow::anyhow!("map {}: {e}", path.display()))?;
        Self::from_mapping(Arc::new(map))
    }

    /// Read `path` into one aligned heap block instead of mapping it.
    pub fn load_heap(path: &Path) -> anyhow::Result<BinArtifact> {
        let map = Mapping::open_heap(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_mapping(Arc::new(map))
    }

    /// Validate an in-memory buffer (copies it into an aligned block).
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<BinArtifact> {
        Self::from_mapping(Arc::new(Mapping::from_bytes(bytes)))
    }

    /// Validate header, section table, and every section checksum.
    pub fn from_mapping(map: Arc<Mapping>) -> anyhow::Result<BinArtifact> {
        let bytes = map.bytes();
        anyhow::ensure!(bytes.len() >= SFB_HEADER_LEN, "artifact shorter than header");
        anyhow::ensure!(bytes[0..8] == SFB_MAGIC, "bad magic (not a sparseflow-bin artifact)");
        let header_crc = read_u32(bytes, 60);
        anyhow::ensure!(crc32(&bytes[0..60]) == header_crc, "header checksum mismatch");
        let format_version = read_u32(bytes, 8);
        anyhow::ensure!(
            format_version == SFB_FORMAT_VERSION,
            "unsupported format version {format_version}"
        );
        let abi_version = read_u32(bytes, 12);
        anyhow::ensure!(abi_version == SFB_ABI_VERSION, "unsupported abi version {abi_version}");
        anyhow::ensure!(
            read_u32(bytes, 16) == SFB_ENDIAN_TAG,
            "artifact written on a foreign-endian host (format is little-endian only)"
        );
        let n_sections = read_u32(bytes, 20) as usize;
        let file_len = read_u64(bytes, 24);
        anyhow::ensure!(
            file_len == bytes.len() as u64,
            "file length field {file_len} != actual {}",
            bytes.len()
        );
        let table_end = SFB_HEADER_LEN + n_sections * SFB_ENTRY_LEN;
        anyhow::ensure!(table_end <= bytes.len(), "section table extends past end of file");
        let table = &bytes[SFB_HEADER_LEN..table_end];
        anyhow::ensure!(crc32(table) == read_u32(bytes, 32), "section table checksum mismatch");

        let mut sections = Vec::with_capacity(n_sections);
        let mut meta: Option<(u64, u64, u64)> = None;
        for i in 0..n_sections {
            let e = i * SFB_ENTRY_LEN;
            let s = SectionInfo {
                kind: read_u32(table, e),
                dtype: read_u32(table, e + 4),
                offset: read_u64(table, e + 8),
                len: read_u64(table, e + 16),
                crc: read_u32(table, e + 24),
            };
            anyhow::ensure!(
                s.offset as usize % SECTION_ALIGN == 0,
                "section {} offset {} not {SECTION_ALIGN}-byte aligned",
                kind_name(s.kind),
                s.offset
            );
            let end = s
                .offset
                .checked_add(s.len)
                .ok_or_else(|| anyhow::anyhow!("section bounds overflow"))?;
            anyhow::ensure!(
                s.offset as usize >= table_end && end <= bytes.len() as u64,
                "section {} [{}, {end}) out of file bounds",
                kind_name(s.kind),
                s.offset
            );
            let payload = &bytes[s.offset as usize..end as usize];
            anyhow::ensure!(
                crc32(payload) == s.crc,
                "section {} checksum mismatch",
                kind_name(s.kind)
            );
            if let Some(expect) = known_dtype(s.kind) {
                anyhow::ensure!(
                    s.dtype == expect,
                    "section {} dtype {} != expected {}",
                    kind_name(s.kind),
                    dtype_name(s.dtype),
                    dtype_name(expect)
                );
            }
            anyhow::ensure!(
                !sections.iter().any(|p: &SectionInfo| p.kind == s.kind),
                "duplicate section kind {}",
                kind_name(s.kind)
            );
            if s.kind == SEC_META {
                anyhow::ensure!(s.len == 24, "meta section must be 3 u64s");
                meta = Some((
                    read_u64(payload, 0),
                    read_u64(payload, 8),
                    read_u64(payload, 16),
                ));
            }
            sections.push(s);
        }
        let (n_neurons, n_conns, group_size) =
            meta.ok_or_else(|| anyhow::anyhow!("artifact has no meta section"))?;
        anyhow::ensure!(
            group_size == GROUP as u64,
            "quant group size {group_size} != compiled-in {GROUP}"
        );
        Ok(BinArtifact {
            map,
            sections,
            n_neurons: n_neurons as usize,
            n_conns: n_conns as usize,
            group_size: group_size as usize,
        })
    }

    fn section(&self, kind: u32) -> Option<&SectionInfo> {
        self.sections.iter().find(|s| s.kind == kind)
    }

    fn section_bytes(&self, s: &SectionInfo) -> &[u8] {
        &self.map.bytes()[s.offset as usize..(s.offset + s.len) as usize]
    }

    /// Borrow a typed pool out of the mapping (no copy).
    pub fn pool<T: Copy>(&self, kind: u32) -> anyhow::Result<Pool<T>> {
        let s = self
            .section(kind)
            .ok_or_else(|| anyhow::anyhow!("artifact missing section {}", kind_name(kind)))?;
        Pool::borrowed(&self.map, self.section_bytes(s))
            .map_err(|e| anyhow::anyhow!("section {}: {e}", kind_name(kind)))
    }

    /// Reconstruct the fused program by borrowing every pool from the
    /// mapping. Zero per-pool copies; all invariants revalidated.
    pub fn fused_program(&self) -> anyhow::Result<FusedProgram> {
        let p = FusedProgram::from_pools(FusedPools {
            ctrl: self.pool(SEC_FUSED_CTRL)?,
            pivots: self.pool(SEC_FUSED_PIVOTS)?,
            bounds: self.pool(SEC_FUSED_BOUNDS)?,
            idx: self.pool(SEC_FUSED_IDX)?,
            weights: self.pool(SEC_FUSED_WEIGHTS)?,
            flags: self.pool(SEC_FUSED_FLAGS)?,
            biases: self.pool(SEC_BIASES)?,
            hidden_sources: self.pool(SEC_HIDDEN_SOURCES)?,
            input_ids: self.pool(SEC_INPUT_IDS)?,
            output_ids: self.pool(SEC_OUTPUT_IDS)?,
            n_neurons: self.n_neurons,
        })?;
        anyhow::ensure!(
            p.n_ops() == self.n_conns,
            "fused idx length {} != meta n_conns {}",
            p.n_ops(),
            self.n_conns
        );
        Ok(p)
    }

    /// Reconstruct the quantized stream program, borrowing the ctrl
    /// stream, qweights, and group table from the mapping.
    pub fn quant_program(&self) -> anyhow::Result<QuantStreamProgram> {
        QuantStreamProgram::from_pools(QuantPools {
            ctrl: self.pool(SEC_QUANT_CTRL)?,
            qweights: self.pool(SEC_QUANT_QWEIGHTS)?,
            groups: self.pool(SEC_QUANT_GROUPS)?,
            biases: self.pool(SEC_BIASES)?,
            hidden_sources: self.pool(SEC_HIDDEN_SOURCES)?,
            input_ids: self.pool(SEC_INPUT_IDS)?,
            output_ids: self.pool(SEC_OUTPUT_IDS)?,
            n_neurons: self.n_neurons,
        })
    }

    /// Validate the `qfused_group_bounds` section against the quant-fused
    /// weight pool and group table: `[0, GROUP, 2·GROUP, …, n_ops]`.
    fn check_qfused_group_bounds(
        &self,
        qweights: &Pool<i8>,
        groups: &Pool<QuantGroup>,
    ) -> anyhow::Result<()> {
        let bounds: Pool<u32> = self.pool(SEC_QFUSED_GROUP_BOUNDS)?;
        anyhow::ensure!(
            bounds.len() == groups.len() + 1,
            "qfused group bounds length {} != n_groups + 1 = {}",
            bounds.len(),
            groups.len() + 1
        );
        for (g, &b) in bounds.iter().enumerate().take(groups.len()) {
            anyhow::ensure!(
                b as usize == g * GROUP,
                "qfused group bound {g} is {b}, want {}",
                g * GROUP
            );
        }
        let last = *bounds.last().unwrap();
        anyhow::ensure!(
            last as usize == qweights.len(),
            "qfused group bounds end at {last}, weight pool has {} elements",
            qweights.len()
        );
        Ok(())
    }

    /// Reconstruct the quant-fused program: the macro-op ctrl/idx/flag
    /// pools are the same `SEC_FUSED_*` sections the f32 fused program
    /// borrows, paired with the `i8` weight pool and per-group
    /// scale/zero-point table. Zero per-pool copies; all invariants
    /// revalidated.
    pub fn quant_fused_program(&self) -> anyhow::Result<QuantFusedProgram> {
        let qweights: Pool<i8> = self.pool(SEC_QFUSED_QWEIGHTS)?;
        let groups: Pool<QuantGroup> = self.pool(SEC_QFUSED_GROUPS)?;
        self.check_qfused_group_bounds(&qweights, &groups)?;
        let p = QuantFusedProgram::from_pools(QuantFusedPools {
            ctrl: self.pool(SEC_FUSED_CTRL)?,
            pivots: self.pool(SEC_FUSED_PIVOTS)?,
            bounds: self.pool(SEC_FUSED_BOUNDS)?,
            idx: self.pool(SEC_FUSED_IDX)?,
            flags: self.pool(SEC_FUSED_FLAGS)?,
            qweights,
            groups,
            biases: self.pool(SEC_BIASES)?,
            hidden_sources: self.pool(SEC_HIDDEN_SOURCES)?,
            input_ids: self.pool(SEC_INPUT_IDS)?,
            output_ids: self.pool(SEC_OUTPUT_IDS)?,
            n_neurons: self.n_neurons,
        })?;
        anyhow::ensure!(
            p.n_ops() == self.n_conns,
            "quant-fused pool length {} != meta n_conns {}",
            p.n_ops(),
            self.n_conns
        );
        Ok(p)
    }

    /// Reconstruct the quant-tiled program for an `M`-slot budget. The
    /// segment structure is budget-dependent and therefore recompiled
    /// from the expanded stream; the `i8` weight pool and group table
    /// are borrowed from the mapping (the quant-fused weight sections —
    /// both programs index weights by stream position).
    pub fn quant_tiled_program(&self, m: usize) -> anyhow::Result<QuantTiledProgram> {
        let qweights: Pool<i8> = self.pool(SEC_QFUSED_QWEIGHTS)?;
        let groups: Pool<QuantGroup> = self.pool(SEC_QFUSED_GROUPS)?;
        self.check_qfused_group_bounds(&qweights, &groups)?;
        let stream = self.stream_program()?;
        let tiled = TiledProgram::from_program(&stream, m)?;
        QuantTiledProgram::from_parts(tiled, qweights, groups)
    }

    /// Reconstruct the interpreted stream program (expands the fused
    /// macro-ops back into per-connection ops; owned, not zero-copy).
    pub fn stream_program(&self) -> anyhow::Result<StreamProgram> {
        let fused = self.fused_program()?;
        StreamProgram::from_raw_parts(
            fused.expand_ops(),
            fused.biases().to_vec(),
            fused.hidden_sources().to_vec(),
            fused.input_ids().to_vec(),
            fused.output_ids().to_vec(),
            self.n_neurons,
        )
    }

    /// Per-neuron layer index, when the producer recorded one.
    pub fn layer_of(&self) -> anyhow::Result<Option<Vec<u32>>> {
        match self.section(SEC_LAYER_OF) {
            None => Ok(None),
            Some(_) => Ok(Some(self.pool::<u32>(SEC_LAYER_OF)?.to_vec())),
        }
    }

    pub fn sections(&self) -> &[SectionInfo] {
        &self.sections
    }

    pub fn mapping(&self) -> &Arc<Mapping> {
        &self.map
    }

    pub fn file_len(&self) -> usize {
        self.map.len()
    }

    pub fn is_mmap(&self) -> bool {
        self.map.is_mmap()
    }

    pub fn n_neurons(&self) -> usize {
        self.n_neurons
    }

    pub fn n_conns(&self) -> usize {
        self.n_conns
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    pub fn n_inputs(&self) -> usize {
        self.section(SEC_INPUT_IDS).map_or(0, |s| s.len as usize / 4)
    }

    pub fn n_outputs(&self) -> usize {
        self.section(SEC_OUTPUT_IDS).map_or(0, |s| s.len as usize / 4)
    }

    /// Header + section dump for `sparseflow inspect`.
    pub fn describe(&self) -> Json {
        let secs: Vec<Json> = self
            .sections
            .iter()
            .map(|s| {
                Json::obj()
                    .set("kind", s.kind as u64)
                    .set("name", kind_name(s.kind))
                    .set("dtype", dtype_name(s.dtype))
                    .set("offset", s.offset)
                    .set("len", s.len)
                    .set("crc32", format!("{:08x}", s.crc))
            })
            .collect();
        Json::obj()
            .set("format", "sparseflow-bin-v1")
            .set("format_version", SFB_FORMAT_VERSION)
            .set("abi_version", SFB_ABI_VERSION)
            .set("file_len", self.file_len() as u64)
            .set("mmap", self.is_mmap())
            .set("n_neurons", self.n_neurons as u64)
            .set("n_conns", self.n_conns as u64)
            .set("group_size", self.group_size as u64)
            .set("n_sections", self.sections.len() as u64)
            .set("sections", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let j = Json::parse(
            r#"{
              "format": "sparseflow-artifacts-v1",
              "artifacts": [{
                "name": "t", "file": "t.hlo.txt", "kind": "ell_mlp", "batch": 4,
                "inputs": [
                  {"shape": [16, 8], "dtype": "float32"},
                  {"shape": [16, 8], "dtype": "int32"},
                  {"shape": [16], "dtype": "float32"},
                  {"shape": [12, 4], "dtype": "float32"}
                ]
              }]
            }"#,
        )
        .unwrap();
        j.to_file(&dir.join("manifest.json")).unwrap();
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("sparseflow-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("t").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dtype, "int32");
        assert_eq!(a.ell_layer_shapes().unwrap(), vec![(16, 8, 12)]);
        assert!(m.find("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![3, 4, 5], dtype: "float32".into() };
        assert_eq!(t.n_elements(), 60);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("sparseflow-no-manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("manifest.json")).ok();
        assert!(Manifest::load(&dir).is_err());
    }
}

#[cfg(test)]
mod bin_tests {
    use super::*;
    use crate::ffnn::generate::{random_mlp, MlpSpec};
    use crate::ffnn::topo::two_optimal_order;
    use crate::util::rng::Pcg64;

    fn sample_net() -> Ffnn {
        random_mlp(&MlpSpec::new(3, 8, 0.7), &mut Pcg64::new(7))
    }

    #[test]
    fn bin_roundtrip_preserves_programs() {
        let net = sample_net();
        let order = two_optimal_order(&net);
        let buf = build_model_artifact(&net, &order);
        let art = BinArtifact::from_bytes(&buf).unwrap();
        assert_eq!(art.n_neurons(), net.n_neurons());
        assert_eq!(art.n_conns(), net.n_conns());
        assert_eq!(art.n_inputs(), net.n_inputs());
        assert_eq!(art.n_outputs(), net.n_outputs());

        let stream = StreamProgram::compile(&net, &order);
        let want_fused = FusedProgram::from_program(&stream);
        let got_fused = art.fused_program().unwrap();
        assert_eq!(got_fused.ctrl(), want_fused.ctrl());
        assert_eq!(got_fused.pivots(), want_fused.pivots());
        assert_eq!(got_fused.bounds(), want_fused.bounds());
        assert_eq!(got_fused.idx(), want_fused.idx());
        assert_eq!(got_fused.weights(), want_fused.weights());
        assert_eq!(got_fused.flags(), want_fused.flags());
        assert_eq!(got_fused.stats().n_ops, want_fused.stats().n_ops);
        assert!(got_fused.is_zero_copy());

        let want_quant = QuantStreamProgram::from_program(&stream);
        let got_quant = art.quant_program().unwrap();
        assert_eq!(got_quant, want_quant);
        assert!(got_quant.is_zero_copy());

        let want_qf = QuantFusedProgram::from_quant(&want_quant);
        let got_qf = art.quant_fused_program().unwrap();
        assert_eq!(got_qf.ctrl(), want_qf.ctrl());
        assert_eq!(got_qf.pivots(), want_qf.pivots());
        assert_eq!(got_qf.bounds(), want_qf.bounds());
        assert_eq!(got_qf.idx(), want_qf.idx());
        assert_eq!(got_qf.flags(), want_qf.flags());
        assert_eq!(got_qf.quantized_weights(), want_qf.quantized_weights());
        assert_eq!(got_qf.groups(), want_qf.groups());
        assert!(got_qf.is_zero_copy());
        // The shared-pool claim, on the load path: the quant-fused
        // macro-op structure is byte-for-byte the f32 fused structure.
        assert_eq!(got_qf.idx(), got_fused.idx());
        assert_eq!(got_qf.flags(), got_fused.flags());

        let got_qt = art.quant_tiled_program(net.n_neurons() + 2).unwrap();
        assert_eq!(got_qt.quantized_weights(), want_quant.quantized_weights());
        assert_eq!(got_qt.groups(), want_quant.groups());
        assert!(art.quant_tiled_program(2).is_err(), "m < 3 must be rejected");

        let got_stream = art.stream_program().unwrap();
        assert_eq!(got_stream.n_ops(), stream.n_ops());
        assert_eq!(art.layer_of().unwrap().as_deref(), net.layer_of());
    }

    #[test]
    fn file_load_mmap_and_heap_agree() {
        let net = sample_net();
        let order = two_optimal_order(&net);
        let path = std::env::temp_dir().join("sparseflow-bin-unit.sfb");
        write_model_artifact(&net, &order, &path).unwrap();
        let mapped = BinArtifact::load(&path).unwrap();
        let heaped = BinArtifact::load_heap(&path).unwrap();
        assert!(!heaped.is_mmap());
        assert_eq!(mapped.sections(), heaped.sections());
        assert_eq!(
            mapped.quant_program().unwrap(),
            heaped.quant_program().unwrap()
        );
        // Pools on the load path borrow the mapping — the zero-copy claim.
        let pool = mapped.pool::<f32>(SEC_BIASES).unwrap();
        assert!(pool.is_borrowed());
        assert!(mapped.mapping().contains(pool.as_ptr() as *const u8) || pool.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_header_and_sections_are_rejected() {
        let net = sample_net();
        let order = two_optimal_order(&net);
        let buf = build_model_artifact(&net, &order);
        // Flip one byte in the header: always caught by the header CRC
        // (or the magic check).
        for at in [0usize, 9, 17, 21, 25, 33, 40, 61] {
            let mut bad = buf.clone();
            bad[at] ^= 0x40;
            assert!(BinArtifact::from_bytes(&bad).is_err(), "header byte {at} undetected");
        }
        // Flip one byte inside each section payload.
        let art = BinArtifact::from_bytes(&buf).unwrap();
        for s in art.sections() {
            if s.len == 0 {
                continue;
            }
            let mut bad = buf.clone();
            bad[s.offset as usize] ^= 0x01;
            assert!(
                BinArtifact::from_bytes(&bad).is_err(),
                "section {} corruption undetected",
                s.kind
            );
        }
        // Truncation anywhere is caught by the file-length field.
        let mut short = buf.clone();
        short.pop();
        assert!(BinArtifact::from_bytes(&short).is_err());
        assert!(BinArtifact::from_bytes(&buf[..40]).is_err());
    }

    #[test]
    fn describe_lists_sections() {
        let net = sample_net();
        let order = two_optimal_order(&net);
        let art = BinArtifact::from_bytes(&build_model_artifact(&net, &order)).unwrap();
        let d = art.describe();
        assert_eq!(
            d.get("format").and_then(Json::as_str),
            Some("sparseflow-bin-v1")
        );
        let secs = d.get("sections").and_then(Json::as_arr).unwrap();
        assert_eq!(secs.len(), art.sections().len());
        assert!(secs.iter().any(|s| {
            s.get("name").and_then(Json::as_str) == Some("fused_weights")
        }));
    }
}
