//! Artifact manifest: `artifacts/manifest.json` written by
//! `python/compile/aot.py`, describing each lowered HLO module and its
//! expected input shapes/dtypes so the Rust loader can validate literals
//! before execution.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Input tensor descriptor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    /// "float32" | "int32" (the only dtypes the artifacts use).
    pub dtype: String,
}

impl TensorSpec {
    pub fn n_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts directory.
    pub file: String,
    /// "ell_mlp" | "dense_mlp".
    pub kind: String,
    /// Batch size baked into the module.
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
}

impl ArtifactMeta {
    /// For ELL artifacts: the (n_out, K, n_in) triple of each layer,
    /// recovered from the weights/indices/bias input shapes.
    pub fn ell_layer_shapes(&self) -> anyhow::Result<Vec<(usize, usize, usize)>> {
        anyhow::ensure!(self.kind == "ell_mlp", "not an ell_mlp artifact");
        anyhow::ensure!(self.inputs.len() % 3 == 1, "inputs must be 3·L + 1");
        let n_layers = self.inputs.len() / 3;
        let mut shapes = Vec::with_capacity(n_layers);
        let x_shape = &self.inputs.last().unwrap().shape;
        let mut n_in = x_shape[0];
        for li in 0..n_layers {
            let w = &self.inputs[3 * li];
            anyhow::ensure!(w.shape.len() == 2, "weights must be 2-D");
            let (n_out, k) = (w.shape[0], w.shape[1]);
            shapes.push((n_out, k, n_in));
            n_in = n_out;
        }
        Ok(shapes)
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let j = Json::from_file(&path).map_err(|e| anyhow::anyhow!("{e}"))?;
        anyhow::ensure!(
            j.get("format").and_then(Json::as_str) == Some("sparseflow-artifacts-v1"),
            "unknown manifest format in {}",
            path.display()
        );
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?
        {
            let get_str = |k: &str| -> anyhow::Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("artifact missing {k}"))?
                    .to_string())
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("artifact missing inputs"))?
                .iter()
                .map(|i| {
                    let shape = i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow::anyhow!("input missing shape"))?
                        .iter()
                        .map(|d| {
                            d.as_u64()
                                .map(|v| v as usize)
                                .ok_or_else(|| anyhow::anyhow!("bad dim"))
                        })
                        .collect::<anyhow::Result<Vec<_>>>()?;
                    let dtype = i
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    Ok(TensorSpec { shape, dtype })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                file: get_str("file")?,
                kind: get_str("kind")?,
                batch: a.get("batch").and_then(Json::as_u64).unwrap_or(0) as usize,
                inputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> anyhow::Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("no artifact named {name:?} (have: {:?})",
                self.artifacts.iter().map(|a| &a.name).collect::<Vec<_>>()))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Default artifacts directory (`SPARSEFLOW_ARTIFACTS` or `artifacts/`).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SPARSEFLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path) {
        let j = Json::parse(
            r#"{
              "format": "sparseflow-artifacts-v1",
              "artifacts": [{
                "name": "t", "file": "t.hlo.txt", "kind": "ell_mlp", "batch": 4,
                "inputs": [
                  {"shape": [16, 8], "dtype": "float32"},
                  {"shape": [16, 8], "dtype": "int32"},
                  {"shape": [16], "dtype": "float32"},
                  {"shape": [12, 4], "dtype": "float32"}
                ]
              }]
            }"#,
        )
        .unwrap();
        j.to_file(&dir.join("manifest.json")).unwrap();
    }

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("sparseflow-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let a = m.find("t").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dtype, "int32");
        assert_eq!(a.ell_layer_shapes().unwrap(), vec![(16, 8, 12)]);
        assert!(m.find("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { shape: vec![3, 4, 5], dtype: "float32".into() };
        assert_eq!(t.n_elements(), 60);
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("sparseflow-no-manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::remove_file(dir.join("manifest.json")).ok();
        assert!(Manifest::load(&dir).is_err());
    }
}
