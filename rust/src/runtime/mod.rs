//! PJRT runtime (L3 ↔ L2 bridge): loads the AOT-compiled HLO text
//! artifacts produced by `python/compile/aot.py`, compiles them on the
//! PJRT CPU client and executes them from the Rust request path. Python
//! never runs at inference time — the artifacts are data.
//!
//! Interchange is HLO *text* (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
#[cfg(not(feature = "xla"))]
#[path = "client_stub.rs"]
pub mod client;
pub mod mmap;
pub mod pack;

pub use artifact::{build_model_artifact, write_model_artifact, ArtifactMeta, BinArtifact};
pub use artifact::{Manifest, SectionInfo};
pub use client::{Runtime, XlaEngine, XlaExecutable};
pub use mmap::{Mapping, Pool, SECTION_ALIGN};
pub use pack::{pack_ell_layers, EllLayer};
