//! Read-only file mappings and the owned/borrowed pool abstraction that
//! makes zero-copy artifact loading possible.
//!
//! [`Mapping`] wraps the platform `mmap(2)` (no external crates — the
//! syscall is declared directly) with a read-to-heap fallback used on
//! unsupported targets, for empty files, or when the caller forces it.
//! Both paths yield one contiguous, immutable, 64-byte-aligned byte
//! region for the mapping's lifetime.
//!
//! [`Pool<T>`] is the slice type the compiled programs store: either an
//! owned `Vec<T>` (compiled in-process) or a borrowed range of a shared
//! [`Mapping`] (loaded from a `sparseflow-bin-v1` artifact). Borrowed
//! pools keep the mapping alive through an [`Arc`], so a loaded program
//! never copies its pools — the paper's thesis applied to model loading:
//! the bytes on disk *are* the execution layout.

use std::path::Path;
use std::sync::Arc;

/// Alignment every artifact section (and the heap fallback) guarantees.
/// mmap bases are page-aligned (4096 % 64 == 0), so a 64-byte-aligned
/// section offset stays 64-byte-aligned in memory on both paths.
pub const SECTION_ALIGN: usize = 64;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    // Declared directly: the container has no `libc` crate. 64-bit unix
    // only — there `off_t` is 64-bit, so the raw symbol is safe to call.
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

enum Backing {
    /// A live `mmap` region (unmapped on drop).
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap,
    /// One 64-byte-aligned heap allocation holding the whole file.
    Heap { layout: std::alloc::Layout },
}

/// An immutable byte region backing zero or more borrowed [`Pool`]s:
/// either a read-only file mapping or its read-to-heap fallback.
pub struct Mapping {
    ptr: *const u8,
    len: usize,
    backing: Backing,
}

// SAFETY: the region is never written after construction and is only
// released on drop, when no pool still holds the keep-alive `Arc`.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    /// Map `path` read-only; falls back to [`Mapping::open_heap`] on
    /// targets without mmap support and for empty files.
    pub fn open(path: &Path) -> std::io::Result<Mapping> {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path)?;
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Self::open_heap(path);
            }
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            // The fd can close now; the mapping keeps the pages alive.
            Ok(Mapping { ptr, len, backing: Backing::Mmap })
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            Self::open_heap(path)
        }
    }

    /// Read the whole file into one 64-byte-aligned heap block — the
    /// portable fallback. Still a single copy for the entire artifact:
    /// borrowed pools slice into this block exactly like into a mapping.
    pub fn open_heap(path: &Path) -> std::io::Result<Mapping> {
        Self::from_bytes(&std::fs::read(path)?)
    }

    /// Heap-backed mapping over a byte buffer (tests, in-memory packing).
    pub fn from_bytes(data: &[u8]) -> std::io::Result<Mapping> {
        let len = data.len();
        let layout = std::alloc::Layout::from_size_align(len.max(1), SECTION_ALIGN)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e.to_string()))?;
        let ptr = unsafe { std::alloc::alloc(layout) };
        if ptr.is_null() {
            std::alloc::handle_alloc_error(layout);
        }
        unsafe { std::ptr::copy_nonoverlapping(data.as_ptr(), ptr, len) };
        Ok(Mapping { ptr, len, backing: Backing::Heap { layout } })
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: ptr/len describe the owned region for self's lifetime.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether this region is a live file mapping (false = heap fallback).
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap)
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            false
        }
    }

    /// Whether `p` points into this region (zero-copy proofs in tests).
    pub fn contains(&self, p: *const u8) -> bool {
        let base = self.ptr as usize;
        (base..base + self.len).contains(&(p as usize))
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        match self.backing {
            #[cfg(all(unix, target_pointer_width = "64"))]
            Backing::Mmap => unsafe {
                sys::munmap(self.ptr as *mut u8, self.len);
            },
            Backing::Heap { layout } => unsafe {
                std::alloc::dealloc(self.ptr as *mut u8, layout);
            },
        }
    }
}

impl std::fmt::Debug for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Mapping")
            .field("len", &self.len)
            .field("mmap", &self.is_mmap())
            .finish()
    }
}

/// A program pool: an owned vector or a borrowed slice of a shared
/// [`Mapping`]. Dereferences to `&[T]`, so execution code is agnostic to
/// where the pool lives.
pub enum Pool<T: Copy> {
    Owned(Vec<T>),
    Borrowed {
        ptr: *const T,
        len: usize,
        /// Keeps the backing region alive for the pool's lifetime.
        map: Arc<Mapping>,
    },
}

// SAFETY: borrowed pools reference an immutable mapping kept alive by
// the Arc; owned pools are plain Vecs.
unsafe impl<T: Copy + Send> Send for Pool<T> {}
unsafe impl<T: Copy + Sync> Sync for Pool<T> {}

impl<T: Copy> Pool<T> {
    /// Borrow `bytes` (a sub-slice of `map`'s region) as a `[T]` pool.
    /// Errors on misalignment or a length that is not a whole number of
    /// elements — corrupt artifacts must fail loudly, never transmute
    /// garbage.
    pub fn borrowed(map: &Arc<Mapping>, bytes: &[u8]) -> anyhow::Result<Pool<T>> {
        let size = std::mem::size_of::<T>();
        let align = std::mem::align_of::<T>();
        anyhow::ensure!(size > 0, "zero-sized pool element");
        anyhow::ensure!(
            bytes.len() % size == 0,
            "section length {} is not a multiple of element size {size}",
            bytes.len()
        );
        anyhow::ensure!(
            bytes.as_ptr() as usize % align == 0,
            "section misaligned for element alignment {align}"
        );
        let inside = bytes.is_empty()
            || (map.contains(bytes.as_ptr()) && map.contains(&bytes[bytes.len() - 1]));
        anyhow::ensure!(inside, "section bytes outside the backing mapping");
        Ok(Pool::Borrowed {
            ptr: bytes.as_ptr() as *const T,
            len: bytes.len() / size,
            map: Arc::clone(map),
        })
    }

    /// Whether the pool borrows a mapping (the zero-copy load path).
    pub fn is_borrowed(&self) -> bool {
        matches!(self, Pool::Borrowed { .. })
    }

    /// The backing mapping of a borrowed pool.
    pub fn mapping(&self) -> Option<&Arc<Mapping>> {
        match self {
            Pool::Owned(_) => None,
            Pool::Borrowed { map, .. } => Some(map),
        }
    }
}

impl<T: Copy> std::ops::Deref for Pool<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        match self {
            Pool::Owned(v) => v,
            // SAFETY: ptr/len were validated by `borrowed` against the
            // mapping, which the Arc keeps alive.
            Pool::Borrowed { ptr, len, .. } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
        }
    }
}

impl<T: Copy> From<Vec<T>> for Pool<T> {
    fn from(v: Vec<T>) -> Pool<T> {
        Pool::Owned(v)
    }
}

impl<T: Copy> Clone for Pool<T> {
    fn clone(&self) -> Pool<T> {
        match self {
            Pool::Owned(v) => Pool::Owned(v.clone()),
            Pool::Borrowed { ptr, len, map } => Pool::Borrowed {
                ptr: *ptr,
                len: *len,
                map: Arc::clone(map),
            },
        }
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for Pool<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let kind = if self.is_borrowed() { "borrowed" } else { "owned" };
        write!(f, "Pool<{kind} x{}>{:?}", self.len(), &self[..])
    }
}

impl<T: Copy + PartialEq> PartialEq for Pool<T> {
    fn eq(&self, other: &Pool<T>) -> bool {
        self[..] == other[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_mapping_roundtrips_bytes() {
        let data: Vec<u8> = (0..=255).collect();
        let m = Mapping::from_bytes(&data).unwrap();
        assert_eq!(m.bytes(), &data[..]);
        assert_eq!(m.len(), 256);
        assert!(!m.is_mmap());
        assert_eq!(m.bytes().as_ptr() as usize % SECTION_ALIGN, 0);
    }

    #[test]
    fn file_mapping_matches_file_contents() {
        let path = std::env::temp_dir().join("sparseflow-mmap-test.bin");
        let data: Vec<u8> = (0..4096u32).flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, &data).unwrap();
        let m = Mapping::open(&path).unwrap();
        assert_eq!(m.bytes(), &data[..]);
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(m.is_mmap());
        let h = Mapping::open_heap(&path).unwrap();
        assert_eq!(h.bytes(), m.bytes());
        assert!(!h.is_mmap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_heap() {
        let path = std::env::temp_dir().join("sparseflow-mmap-empty.bin");
        std::fs::write(&path, b"").unwrap();
        let m = Mapping::open(&path).unwrap();
        assert!(m.is_empty());
        assert!(!m.is_mmap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn borrowed_pool_derefs_without_copying() {
        let words: Vec<u32> = (0..100).map(|i| i * 3).collect();
        let bytes: Vec<u8> = words.iter().flat_map(|w| w.to_le_bytes()).collect();
        let map = Arc::new(Mapping::from_bytes(&bytes).unwrap());
        let pool: Pool<u32> = Pool::borrowed(&map, map.bytes()).unwrap();
        assert!(pool.is_borrowed());
        assert_eq!(&pool[..], &words[..]);
        assert!(map.contains(pool.as_ptr() as *const u8));
        let owned: Pool<u32> = words.clone().into();
        assert!(!owned.is_borrowed());
        assert_eq!(pool, owned);
    }

    #[test]
    fn misaligned_or_ragged_sections_rejected() {
        let map = Arc::new(Mapping::from_bytes(&[0u8; 64]).unwrap());
        // Length not a multiple of 4.
        assert!(Pool::<u32>::borrowed(&map, &map.bytes()[..7]).is_err());
        // Offset 2 breaks u32 alignment.
        assert!(Pool::<u32>::borrowed(&map, &map.bytes()[2..6]).is_err());
        // Aligned sub-slice is fine.
        assert!(Pool::<u32>::borrowed(&map, &map.bytes()[4..12]).is_ok());
    }

    #[test]
    fn pool_clone_shares_the_mapping() {
        let map = Arc::new(Mapping::from_bytes(&[1u8, 2, 3, 4]).unwrap());
        let pool: Pool<u8> = Pool::borrowed(&map, map.bytes()).unwrap();
        let copy = pool.clone();
        drop(pool);
        assert_eq!(&copy[..], &[1, 2, 3, 4]);
        assert!(copy.is_borrowed());
    }
}
