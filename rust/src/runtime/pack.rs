//! ELL packing: convert a layered [`Ffnn`] into the padded ELLPACK tables
//! the AOT artifacts expect as inputs (weights/indices `[n_out, K]`,
//! bias `[n_out]` per layer). Padded slots carry (weight 0, index 0), the
//! convention `python/compile/kernels/ell_spmm.py` defines.
//!
//! Also describes **compressed quantized stream programs** in the
//! artifact manifest (kind `"quant_stream"`): the program's byte streams
//! map onto typed tensors (uint8 control stream, int8 weights, f32
//! `[G, 2]` group parameters, f32 biases) so `Manifest::load` validates
//! a quantized model exactly like an ELL one. The byte payload itself
//! ships in the `sparseflow-quant-v1` JSON file
//! (`ffnn::serde::save_quant`), referenced by the manifest entry.

use super::artifact::TensorSpec;
use crate::exec::quant::QuantStreamProgram;
use crate::ffnn::graph::{Ffnn, NeuronId};
use crate::util::json::Json;

/// One ELL-packed layer.
#[derive(Clone, Debug)]
pub struct EllLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub k: usize,
    /// Row-major `[n_out, K]`.
    pub weights: Vec<f32>,
    /// Row-major `[n_out, K]`, values index the *previous layer position*.
    pub indices: Vec<i32>,
    pub bias: Vec<f32>,
}

impl EllLayer {
    /// Pack the connections between two consecutive layers with a fixed
    /// row width `k` (≥ the max in-degree within this layer pair).
    pub fn pack(
        net: &Ffnn,
        in_ids: &[NeuronId],
        out_ids: &[NeuronId],
        k: usize,
    ) -> anyhow::Result<EllLayer> {
        let mut col_of = vec![u32::MAX; net.n_neurons()];
        for (i, &v) in in_ids.iter().enumerate() {
            col_of[v as usize] = i as u32;
        }
        let (n_in, n_out) = (in_ids.len(), out_ids.len());
        let mut weights = vec![0.0f32; n_out * k];
        let mut indices = vec![0i32; n_out * k];
        let mut bias = Vec::with_capacity(n_out);
        for (r, &o) in out_ids.iter().enumerate() {
            let conns = net.in_conns(o);
            anyhow::ensure!(
                conns.len() <= k,
                "neuron {o}: in-degree {} exceeds ELL width K={k}",
                conns.len()
            );
            for (slot, &ci) in conns.iter().enumerate() {
                let c = net.conn(ci as usize);
                let col = col_of[c.src as usize];
                anyhow::ensure!(col != u32::MAX, "connection crosses non-consecutive layers");
                weights[r * k + slot] = c.weight;
                indices[r * k + slot] = col as i32;
            }
            bias.push(net.initial(o));
        }
        Ok(EllLayer {
            n_in,
            n_out,
            k,
            weights,
            indices,
            bias,
        })
    }

    /// Maximum in-degree over `out_ids` (the tightest valid K).
    pub fn max_in_degree(net: &Ffnn, out_ids: &[NeuronId]) -> usize {
        out_ids.iter().map(|&o| net.in_degree(o)).max().unwrap_or(0)
    }
}

/// Pack a whole layered network with per-layer widths `ks`
/// (`ks.len() == n_layers − 1`); each `ks[i]` must cover that layer's max
/// in-degree.
pub fn pack_ell_layers(net: &Ffnn, ks: &[usize]) -> anyhow::Result<Vec<EllLayer>> {
    let layers = net
        .layers()
        .ok_or_else(|| anyhow::anyhow!("ELL packing requires a layered network"))?;
    anyhow::ensure!(
        ks.len() == layers.len() - 1,
        "need {} K values, got {}",
        layers.len() - 1,
        ks.len()
    );
    let mut out = Vec::with_capacity(ks.len());
    for (li, &k) in ks.iter().enumerate() {
        out.push(EllLayer::pack(net, &layers[li], &layers[li + 1], k)?);
    }
    Ok(out)
}

/// Tensor layout of a compressed quantized stream program in the
/// artifact format, in manifest order: control stream (uint8), quantized
/// weights (int8), group scale/zero-point pairs (f32 `[G, 2]`), biases
/// (f32 `[N]`), and the batched input (`[n_inputs, batch]`).
pub fn quant_tensor_specs(p: &QuantStreamProgram, batch: usize) -> Vec<TensorSpec> {
    vec![
        TensorSpec {
            shape: vec![p.ctrl_bytes().len()],
            dtype: "uint8".to_string(),
        },
        TensorSpec {
            shape: vec![p.n_ops()],
            dtype: "int8".to_string(),
        },
        TensorSpec {
            shape: vec![p.groups().len(), 2],
            dtype: "float32".to_string(),
        },
        TensorSpec {
            shape: vec![p.n_neurons()],
            dtype: "float32".to_string(),
        },
        TensorSpec {
            shape: vec![p.input_ids().len(), batch],
            dtype: "float32".to_string(),
        },
    ]
}

/// Manifest entry (kind `"quant_stream"`) describing a compressed
/// program stored at `file` (a `sparseflow-quant-v1` JSON payload).
pub fn quant_manifest_entry(
    name: &str,
    file: &str,
    p: &QuantStreamProgram,
    batch: usize,
) -> Json {
    let inputs: Vec<Json> = quant_tensor_specs(p, batch)
        .into_iter()
        .map(|t| {
            Json::obj()
                .set(
                    "shape",
                    Json::Arr(t.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
                )
                .set("dtype", t.dtype.as_str())
        })
        .collect();
    Json::obj()
        .set("name", name)
        .set("file", file)
        .set("kind", "quant_stream")
        .set("batch", batch)
        .set("inputs", Json::Arr(inputs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_layered, random_mlp, MlpSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn pack_shapes_and_padding() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(2, 12, 0.3), &mut rng);
        let layers = net.layers().unwrap();
        let kmax = EllLayer::max_in_degree(&net, &layers[1]);
        let l = EllLayer::pack(&net, &layers[0], &layers[1], kmax + 2).unwrap();
        assert_eq!(l.weights.len(), l.n_out * l.k);
        assert_eq!(l.indices.len(), l.n_out * l.k);
        // Padded slots: weight 0, index 0.
        for r in 0..l.n_out {
            let deg = net.in_degree(layers[1][r]);
            for s in deg..l.k {
                assert_eq!(l.weights[r * l.k + s], 0.0);
                assert_eq!(l.indices[r * l.k + s], 0);
            }
        }
    }

    #[test]
    fn pack_rejects_small_k() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_layered(&[8, 8], 0.9, 1.0, &mut rng);
        let layers = net.layers().unwrap();
        let kmax = EllLayer::max_in_degree(&net, &layers[1]);
        assert!(kmax > 1);
        assert!(EllLayer::pack(&net, &layers[0], &layers[1], kmax - 1).is_err());
    }

    #[test]
    fn pack_whole_network() {
        let mut rng = Pcg64::seed_from(3);
        let net = random_layered(&[10, 14, 6], 0.4, 1.0, &mut rng);
        let ells = pack_ell_layers(&net, &[10, 14]).unwrap();
        assert_eq!(ells.len(), 2);
        assert_eq!(ells[0].n_in, 10);
        assert_eq!(ells[1].n_out, 6);
        // Total non-padding weights = W.
        let nz: usize = ells
            .iter()
            .flat_map(|l| l.weights.iter())
            .filter(|w| **w != 0.0)
            .count();
        // (Generated Gaussian weights are never exactly 0.)
        assert_eq!(nz, net.n_conns());
    }

    #[test]
    fn pack_wrong_k_count_rejected() {
        let mut rng = Pcg64::seed_from(4);
        let net = random_layered(&[6, 6, 6], 0.5, 1.0, &mut rng);
        assert!(pack_ell_layers(&net, &[6]).is_err());
    }

    /// The compressed program round-trips through the artifact format:
    /// manifest entry + `sparseflow-quant-v1` payload load back to an
    /// identical program.
    #[test]
    fn quant_program_roundtrips_through_artifact_format() {
        use crate::ffnn::topo::two_optimal_order;
        use crate::model::{Format, Model};
        use crate::runtime::Manifest;

        let mut rng = Pcg64::seed_from(5);
        let net = random_mlp(&MlpSpec::new(3, 12, 0.4), &mut rng);
        let program = QuantStreamProgram::compress(&net, &two_optimal_order(&net));

        let dir = std::env::temp_dir().join("sparseflow-quant-artifact-test");
        std::fs::create_dir_all(&dir).unwrap();
        Model::from_quant(program.clone())
            .save(&dir.join("mlp.quant.json"), Format::QuantJsonV1)
            .unwrap();
        let manifest_json = Json::obj()
            .set("format", "sparseflow-artifacts-v1")
            .set(
                "artifacts",
                Json::Arr(vec![quant_manifest_entry(
                    "mlp-i8",
                    "mlp.quant.json",
                    &program,
                    16,
                )]),
            );
        manifest_json.to_file(&dir.join("manifest.json")).unwrap();

        let manifest = Manifest::load(&dir).unwrap();
        let meta = manifest.find("mlp-i8").unwrap();
        assert_eq!(meta.kind, "quant_stream");
        assert_eq!(meta.batch, 16);
        let specs = quant_tensor_specs(&program, 16);
        assert_eq!(meta.inputs, specs);
        assert_eq!(meta.inputs[0].dtype, "uint8");
        assert_eq!(meta.inputs[1].dtype, "int8");
        assert_eq!(meta.inputs[1].n_elements(), program.n_ops());
        assert_eq!(meta.inputs[2].shape, vec![program.groups().len(), 2]);

        let loaded = Model::load(&manifest.hlo_path(meta)).unwrap();
        assert_eq!(loaded.quant().unwrap(), &program);
        std::fs::remove_dir_all(&dir).ok();
    }
}
