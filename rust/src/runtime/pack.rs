//! ELL packing: convert a layered [`Ffnn`] into the padded ELLPACK tables
//! the AOT artifacts expect as inputs (weights/indices `[n_out, K]`,
//! bias `[n_out]` per layer). Padded slots carry (weight 0, index 0), the
//! convention `python/compile/kernels/ell_spmm.py` defines.

use crate::ffnn::graph::{Ffnn, NeuronId};

/// One ELL-packed layer.
#[derive(Clone, Debug)]
pub struct EllLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub k: usize,
    /// Row-major `[n_out, K]`.
    pub weights: Vec<f32>,
    /// Row-major `[n_out, K]`, values index the *previous layer position*.
    pub indices: Vec<i32>,
    pub bias: Vec<f32>,
}

impl EllLayer {
    /// Pack the connections between two consecutive layers with a fixed
    /// row width `k` (≥ the max in-degree within this layer pair).
    pub fn pack(
        net: &Ffnn,
        in_ids: &[NeuronId],
        out_ids: &[NeuronId],
        k: usize,
    ) -> anyhow::Result<EllLayer> {
        let mut col_of = vec![u32::MAX; net.n_neurons()];
        for (i, &v) in in_ids.iter().enumerate() {
            col_of[v as usize] = i as u32;
        }
        let (n_in, n_out) = (in_ids.len(), out_ids.len());
        let mut weights = vec![0.0f32; n_out * k];
        let mut indices = vec![0i32; n_out * k];
        let mut bias = Vec::with_capacity(n_out);
        for (r, &o) in out_ids.iter().enumerate() {
            let conns = net.in_conns(o);
            anyhow::ensure!(
                conns.len() <= k,
                "neuron {o}: in-degree {} exceeds ELL width K={k}",
                conns.len()
            );
            for (slot, &ci) in conns.iter().enumerate() {
                let c = net.conn(ci as usize);
                let col = col_of[c.src as usize];
                anyhow::ensure!(col != u32::MAX, "connection crosses non-consecutive layers");
                weights[r * k + slot] = c.weight;
                indices[r * k + slot] = col as i32;
            }
            bias.push(net.initial(o));
        }
        Ok(EllLayer {
            n_in,
            n_out,
            k,
            weights,
            indices,
            bias,
        })
    }

    /// Maximum in-degree over `out_ids` (the tightest valid K).
    pub fn max_in_degree(net: &Ffnn, out_ids: &[NeuronId]) -> usize {
        out_ids.iter().map(|&o| net.in_degree(o)).max().unwrap_or(0)
    }
}

/// Pack a whole layered network with per-layer widths `ks`
/// (`ks.len() == n_layers − 1`); each `ks[i]` must cover that layer's max
/// in-degree.
pub fn pack_ell_layers(net: &Ffnn, ks: &[usize]) -> anyhow::Result<Vec<EllLayer>> {
    let layers = net
        .layers()
        .ok_or_else(|| anyhow::anyhow!("ELL packing requires a layered network"))?;
    anyhow::ensure!(
        ks.len() == layers.len() - 1,
        "need {} K values, got {}",
        layers.len() - 1,
        ks.len()
    );
    let mut out = Vec::with_capacity(ks.len());
    for (li, &k) in ks.iter().enumerate() {
        out.push(EllLayer::pack(net, &layers[li], &layers[li + 1], k)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_layered, random_mlp, MlpSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn pack_shapes_and_padding() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(2, 12, 0.3), &mut rng);
        let layers = net.layers().unwrap();
        let kmax = EllLayer::max_in_degree(&net, &layers[1]);
        let l = EllLayer::pack(&net, &layers[0], &layers[1], kmax + 2).unwrap();
        assert_eq!(l.weights.len(), l.n_out * l.k);
        assert_eq!(l.indices.len(), l.n_out * l.k);
        // Padded slots: weight 0, index 0.
        for r in 0..l.n_out {
            let deg = net.in_degree(layers[1][r]);
            for s in deg..l.k {
                assert_eq!(l.weights[r * l.k + s], 0.0);
                assert_eq!(l.indices[r * l.k + s], 0);
            }
        }
    }

    #[test]
    fn pack_rejects_small_k() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_layered(&[8, 8], 0.9, 1.0, &mut rng);
        let layers = net.layers().unwrap();
        let kmax = EllLayer::max_in_degree(&net, &layers[1]);
        assert!(kmax > 1);
        assert!(EllLayer::pack(&net, &layers[0], &layers[1], kmax - 1).is_err());
    }

    #[test]
    fn pack_whole_network() {
        let mut rng = Pcg64::seed_from(3);
        let net = random_layered(&[10, 14, 6], 0.4, 1.0, &mut rng);
        let ells = pack_ell_layers(&net, &[10, 14]).unwrap();
        assert_eq!(ells.len(), 2);
        assert_eq!(ells[0].n_in, 10);
        assert_eq!(ells[1].n_out, 6);
        // Total non-padding weights = W.
        let nz: usize = ells
            .iter()
            .flat_map(|l| l.weights.iter())
            .filter(|w| **w != 0.0)
            .count();
        // (Generated Gaussian weights are never exactly 0.)
        assert_eq!(nz, net.n_conns());
    }

    #[test]
    fn pack_wrong_k_count_rejected() {
        let mut rng = Pcg64::seed_from(4);
        let net = random_layered(&[6, 6, 6], 0.5, 1.0, &mut rng);
        assert!(pack_ell_layers(&net, &[6]).is_err());
    }
}
