//! Extremal FFNN constructions from the paper's proofs (§III): the
//! instances showing the Theorem-1 bounds are tight (Proposition 1) and
//! that layer-wise inference can be arbitrarily worse in write-I/Os
//! (Proposition 2). Used by the `thm1`/`prop2` benches and the test suite.

use super::graph::{Conn, Ffnn, NeuronKind};
use crate::util::rng::Pcg64;

/// Lemma 1: a layered FFNN in which any two consecutive layers fit
/// together in M−1 slots admits inference exactly at the lower bound
/// (N+W reads, S writes). Builds dense consecutive-layer connectivity over
/// the given `sizes` (caller ensures `sizes[i] + sizes[i+1] ≤ M−1`).
pub fn lemma1_net(sizes: &[usize], rng: &mut Pcg64) -> Ffnn {
    assert!(sizes.len() >= 2);
    let n: usize = sizes.iter().sum();
    let mut kinds = Vec::with_capacity(n);
    let mut layer_of = Vec::with_capacity(n);
    let mut base = Vec::new();
    let mut acc = 0u32;
    for (li, &sz) in sizes.iter().enumerate() {
        base.push(acc);
        for _ in 0..sz {
            kinds.push(if li == 0 {
                NeuronKind::Input
            } else if li == sizes.len() - 1 {
                NeuronKind::Output
            } else {
                NeuronKind::Hidden
            });
            layer_of.push(li as u32);
            acc += 1;
        }
    }
    let initial: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut conns = Vec::new();
    for li in 0..sizes.len() - 1 {
        for s in 0..sizes[li] {
            for t in 0..sizes[li + 1] {
                conns.push(Conn {
                    src: base[li] + s as u32,
                    dst: base[li + 1] + t as u32,
                    weight: rng.normal() as f32,
                });
            }
        }
    }
    Ffnn::new(kinds, initial, conns)
        .expect("valid layered net")
        .with_layers(layer_of)
}

/// Lemma 2: a "star tree" — `n_inputs` input neurons all feeding a single
/// output neuron. Attains the upper bounds: every connection requires
/// reading a fresh input value, so rI/Os = 2W + N − I and total
/// = 2(W + N − I) (as W = I and the only non-input is the output).
pub fn lemma2_tree(n_inputs: usize, rng: &mut Pcg64) -> Ffnn {
    assert!(n_inputs >= 1);
    let mut kinds = vec![NeuronKind::Input; n_inputs];
    kinds.push(NeuronKind::Output);
    let initial: Vec<f32> = (0..=n_inputs).map(|_| rng.normal() as f32).collect();
    let out = n_inputs as u32;
    let conns: Vec<Conn> = (0..n_inputs as u32)
        .map(|i| Conn {
            src: i,
            dst: out,
            weight: rng.normal() as f32,
        })
        .collect();
    Ffnn::new(kinds, initial, conns).expect("valid star")
}

/// Lemma 3: FFNN whose write-I/Os approach the N−I upper bound: `n_inputs`
/// inputs, a hidden layer of `n_hidden`, and `n_outputs` outputs with
/// S ≫ h so that S/(S+h) → 1. Dense consecutive connectivity.
pub fn lemma3_net(n_inputs: usize, n_hidden: usize, n_outputs: usize, rng: &mut Pcg64) -> Ffnn {
    lemma1_net(&[n_inputs, n_hidden, n_outputs], rng)
}

/// Proposition 2: the "2M chains" network. One input neuron fans out to
/// `2m` parallel chains of `c` hidden neurons each, all merging into one
/// output neuron. Layer-after-layer inference with fast memory M needs
/// ≥ M·c write-I/Os; chain-after-chain needs at most 1.
pub fn prop2_chains(m: usize, c: usize, rng: &mut Pcg64) -> Ffnn {
    assert!(m >= 1 && c >= 1);
    let chains = 2 * m;
    let n = 1 + chains * c + 1;
    let mut kinds = Vec::with_capacity(n);
    let mut layer_of = Vec::with_capacity(n);
    kinds.push(NeuronKind::Input);
    layer_of.push(0);
    for _ in 0..chains * c {
        kinds.push(NeuronKind::Hidden);
        layer_of.push(0); // filled below
    }
    kinds.push(NeuronKind::Output);
    let initial: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    // Neuron id of chain k, position j (0-based): 1 + k*c + j.
    let id = |k: usize, j: usize| (1 + k * c + j) as u32;
    let out = (n - 1) as u32;
    let mut conns = Vec::with_capacity(chains * (c + 1));
    for k in 0..chains {
        conns.push(Conn {
            src: 0,
            dst: id(k, 0),
            weight: rng.normal() as f32,
        });
        for j in 0..c - 1 {
            conns.push(Conn {
                src: id(k, j),
                dst: id(k, j + 1),
                weight: rng.normal() as f32,
            });
        }
        conns.push(Conn {
            src: id(k, c - 1),
            dst: out,
            weight: rng.normal() as f32,
        });
    }
    for (i, lo) in layer_of.iter_mut().enumerate().skip(1) {
        *lo = (((i - 1) % c) + 1) as u32;
    }
    let mut layer_of = layer_of;
    layer_of.push((c + 1) as u32);

    Ffnn::new(kinds, initial, conns)
        .expect("valid chains net")
        .with_layers(layer_of)
}

/// The *chain-after-chain* connection order for [`prop2_chains`]: finish
/// each chain end-to-end before starting the next (the optimal strategy in
/// the proof of Proposition 2).
pub fn prop2_chain_order(m: usize, c: usize) -> super::topo::ConnOrder {
    let chains = 2 * m;
    // Connections were pushed chain-major already: chain k contributes the
    // contiguous block [k*(c+1), (k+1)*(c+1)). That *is* chain-after-chain.
    super::topo::ConnOrder::identity(chains * (c + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma1_sizes() {
        let net = lemma1_net(&[3, 4, 2], &mut Pcg64::seed_from(1));
        assert_eq!(net.n_neurons(), 9);
        assert_eq!(net.n_conns(), 3 * 4 + 4 * 2);
        assert_eq!(net.n_inputs(), 3);
        assert_eq!(net.n_outputs(), 2);
        assert!(net.is_connected());
    }

    #[test]
    fn lemma2_star_counts() {
        let net = lemma2_tree(10, &mut Pcg64::seed_from(2));
        assert_eq!(net.n_neurons(), 11);
        assert_eq!(net.n_conns(), 10);
        assert_eq!(net.n_inputs(), 10);
        assert_eq!(net.n_outputs(), 1);
        // W = I and N − I = 1: upper bound total = 2(W + N − I) = 22.
    }

    #[test]
    fn lemma3_output_heavy() {
        let net = lemma3_net(2, 3, 50, &mut Pcg64::seed_from(3));
        assert_eq!(net.n_outputs(), 50);
        let s = net.n_outputs() as f64;
        let non_input = (net.n_neurons() - net.n_inputs()) as f64;
        assert!(s / non_input > 0.9, "S must dominate N − I");
    }

    #[test]
    fn prop2_chains_structure() {
        let (m, c) = (3, 4);
        let net = prop2_chains(m, c, &mut Pcg64::seed_from(4));
        assert_eq!(net.n_neurons(), 1 + 2 * m * c + 1);
        assert_eq!(net.n_conns(), 2 * m * (c + 1));
        // Every hidden neuron: exactly one in, one out.
        for v in 1..=(2 * m * c) as u32 {
            assert_eq!(net.in_degree(v), 1);
            assert_eq!(net.out_degree(v), 1);
        }
        // Input fans out to all chains, output collects all chains.
        assert_eq!(net.out_degree(0), 2 * m);
        assert_eq!(net.in_degree((net.n_neurons() - 1) as u32), 2 * m);
        assert!(net.is_connected());
    }

    #[test]
    fn prop2_chain_order_is_topological() {
        let (m, c) = (2, 3);
        let net = prop2_chains(m, c, &mut Pcg64::seed_from(5));
        let order = prop2_chain_order(m, c);
        assert!(order.is_topological(&net));
    }

    #[test]
    fn prop2_layerwise_order_exists() {
        let net = prop2_chains(2, 3, &mut Pcg64::seed_from(6));
        let order = super::super::topo::layerwise_order(&net);
        assert!(order.is_topological(&net));
    }
}
