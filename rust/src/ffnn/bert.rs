//! BERT-like encoder MLP with magnitude pruning (paper §VI.A.5, Figs 6/8).
//!
//! The paper takes one of BERT_LARGE's depth-2 FFNNs (weight matrices
//! 1024×4096 and 4096×1024) from a *pre-trained* checkpoint and prunes the
//! smallest-magnitude weights. No pretrained checkpoint is available in
//! this environment, so we substitute synthetic Gaussian weights of the
//! same shapes (DESIGN.md §5): the I/O structure after magnitude pruning
//! depends only on the sparsity *pattern*, and pruning i.i.d. Gaussian
//! weights by global magnitude yields the same unstructured per-layer
//! pattern statistics the paper's counts exercise.

use super::graph::{Conn, Ffnn, NeuronKind};
use crate::util::rng::Pcg64;

/// Shape of the BERT encoder MLP. Defaults to BERT_LARGE: 1024-4096-1024.
#[derive(Clone, Copy, Debug)]
pub struct BertSpec {
    pub d_model: usize,
    pub d_ff: usize,
    /// Fraction of weights kept after magnitude pruning, in (0, 1].
    pub density: f64,
}

impl BertSpec {
    pub fn bert_large(density: f64) -> BertSpec {
        BertSpec {
            d_model: 1024,
            d_ff: 4096,
            density,
        }
    }

    /// Reduced-size variant for tests/quick runs.
    pub fn small(density: f64) -> BertSpec {
        BertSpec {
            d_model: 64,
            d_ff: 256,
            density,
        }
    }
}

/// Generate the pruned BERT-like MLP: d_model inputs → d_ff hidden →
/// d_model outputs. Weights are N(0, 1); magnitude pruning keeps the
/// `density` fraction with the largest |w| *globally across both
/// matrices* (matching "removing the connections with the weights of
/// smallest absolute value"). Neurons that lose all their connections are
/// dropped so the returned network is the connected structure whose sizes
/// (N, W, I, S) enter the Theorem-1 bounds.
pub fn bert_mlp(spec: &BertSpec, rng: &mut Pcg64) -> Ffnn {
    assert!(spec.density > 0.0 && spec.density <= 1.0);
    let (dm, dff) = (spec.d_model, spec.d_ff);
    let n = dm + dff + dm;

    let mut kinds = Vec::with_capacity(n);
    let mut layer_of = Vec::with_capacity(n);
    for _ in 0..dm {
        kinds.push(NeuronKind::Input);
        layer_of.push(0);
    }
    for _ in 0..dff {
        kinds.push(NeuronKind::Hidden);
        layer_of.push(1);
    }
    for _ in 0..dm {
        kinds.push(NeuronKind::Output);
        layer_of.push(2);
    }
    let initial: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();

    // Dense weights for both matrices, then a global magnitude threshold.
    let total = dm * dff + dff * dm;
    let keep = ((total as f64) * spec.density).round() as usize;
    let mut weights: Vec<f32> = (0..total).map(|_| rng.normal() as f32).collect();

    // Global threshold = keep-th largest |w| (selection without full sort).
    let threshold = if keep >= total {
        f32::NEG_INFINITY
    } else {
        let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
        let idx = total - keep; // elements ≥ mags[idx] are kept
        mags.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        mags[idx]
    };

    let mut conns = Vec::with_capacity(keep + 16);
    // Matrix 1: inputs (0..dm) → hidden (dm..dm+dff).
    let mut widx = 0;
    for i in 0..dm {
        for j in 0..dff {
            let w = weights[widx];
            widx += 1;
            if w.abs() >= threshold {
                conns.push(Conn {
                    src: i as u32,
                    dst: (dm + j) as u32,
                    weight: w,
                });
            }
        }
    }
    // Matrix 2: hidden → outputs (dm+dff..).
    for j in 0..dff {
        for k in 0..dm {
            let w = weights[widx];
            widx += 1;
            if w.abs() >= threshold {
                conns.push(Conn {
                    src: (dm + j) as u32,
                    dst: (dm + dff + k) as u32,
                    weight: w,
                });
            }
        }
    }
    weights.clear();

    Ffnn::new(kinds, initial, conns)
        .expect("bert generator produces valid DAGs")
        .with_layers(layer_of)
        .drop_isolated()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_shape() {
        let net = bert_mlp(&BertSpec::small(1.0), &mut Pcg64::seed_from(1));
        let (dm, dff) = (64, 256);
        assert_eq!(net.n_neurons(), dm + dff + dm);
        assert_eq!(net.n_conns(), 2 * dm * dff);
        assert_eq!(net.n_inputs(), dm);
        assert_eq!(net.n_outputs(), dm);
    }

    #[test]
    fn pruning_keeps_density_fraction() {
        for &d in &[0.5, 0.1, 0.01] {
            let net = bert_mlp(&BertSpec::small(d), &mut Pcg64::seed_from(2));
            let total = 2 * 64 * 256;
            let expected = (total as f64 * d).round();
            let got = net.n_conns() as f64;
            assert!(
                (got - expected).abs() <= expected * 0.02 + 2.0,
                "density {d}: kept {got}, expected ≈ {expected}"
            );
        }
    }

    #[test]
    fn kept_weights_dominate_dropped() {
        // Magnitude pruning: min kept |w| ≥ implied threshold; sanity-check
        // that at 10% density the smallest kept weight is well above the
        // Gaussian median.
        let net = bert_mlp(&BertSpec::small(0.1), &mut Pcg64::seed_from(3));
        let min_kept = net
            .conns()
            .iter()
            .map(|c| c.weight.abs())
            .fold(f32::INFINITY, f32::min);
        assert!(min_kept > 0.6745, "10% tail of N(0,1) starts around 1.64; got {min_kept}");
    }

    #[test]
    fn isolated_neurons_dropped_at_high_sparsity() {
        let net = bert_mlp(&BertSpec::small(0.005), &mut Pcg64::seed_from(4));
        for v in 0..net.n_neurons() as u32 {
            assert!(net.in_degree(v) + net.out_degree(v) > 0);
        }
    }

    #[test]
    fn deterministic() {
        let a = bert_mlp(&BertSpec::small(0.2), &mut Pcg64::seed_from(5));
        let b = bert_mlp(&BertSpec::small(0.2), &mut Pcg64::seed_from(5));
        assert_eq!(a.conns(), b.conns());
    }
}
