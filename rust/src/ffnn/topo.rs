//! Topological orders of *connections* (paper §II.A).
//!
//! A computation strategy = a topological order of the connections + an
//! eviction policy. This module provides the order abstraction
//! ([`ConnOrder`]), validity checking, and the two canonical constructions:
//!
//! * [`two_optimal_order`] — the proof-of-Theorem-1 order: fix a topological
//!   order of the non-input neurons and sort connections by the position of
//!   their *output* neuron. Guarantees ≤ 2·(W+N−I) total I/Os.
//! * [`layerwise_order`] — matrix-vector-multiplication order: connections
//!   grouped layer after layer (the "standard way"; Appendix A orders the
//!   initial layout like this, which coincides with the 2-optimal
//!   construction on layered nets).

use super::graph::{Ffnn, NeuronId};

/// A permutation of connection indices; `order[k]` is the index (into
/// `Ffnn::conns()`) of the k-th connection processed by Algorithm 1.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConnOrder {
    perm: Vec<u32>,
}

impl ConnOrder {
    /// Identity order (connections as stored).
    pub fn identity(n_conns: usize) -> ConnOrder {
        ConnOrder {
            perm: (0..n_conns as u32).collect(),
        }
    }

    pub fn from_perm(perm: Vec<u32>) -> ConnOrder {
        ConnOrder { perm }
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    pub fn as_slice(&self) -> &[u32] {
        &self.perm
    }

    pub fn as_mut_slice(&mut self) -> &mut [u32] {
        &mut self.perm
    }

    /// Position of each connection in the order (inverse permutation).
    pub fn positions(&self) -> Vec<u32> {
        let mut pos = vec![0u32; self.perm.len()];
        for (k, &ci) in self.perm.iter().enumerate() {
            pos[ci as usize] = k as u32;
        }
        pos
    }

    /// Check that this is a permutation and a *topological* order of the
    /// connections: whenever `e_i.dst == e_j.src`, `e_i` comes first.
    pub fn is_topological(&self, net: &Ffnn) -> bool {
        perm_is_topological(net, &self.perm)
    }

    /// Consume the order, returning the underlying permutation without
    /// copying (used to recycle allocations in the annealing loop).
    pub fn into_perm(self) -> Vec<u32> {
        self.perm
    }
}

/// Slice form of [`ConnOrder::is_topological`] — the borrowed-perm
/// simulate path ([`crate::sim::Simulator::run_perm`] and friends)
/// validates candidate orders without materializing a `ConnOrder`.
pub fn perm_is_topological(net: &Ffnn, perm: &[u32]) -> bool {
    if perm.len() != net.n_conns() {
        return false;
    }
    let mut seen = vec![false; net.n_conns()];
    for &ci in perm {
        let ci = ci as usize;
        if ci >= net.n_conns() || seen[ci] {
            return false;
        }
        seen[ci] = true;
    }
    let mut pos = vec![0u32; perm.len()];
    for (k, &ci) in perm.iter().enumerate() {
        pos[ci as usize] = k as u32;
    }
    // For each neuron: the last incoming connection must precede the
    // first outgoing connection.
    for v in 0..net.n_neurons() as NeuronId {
        let last_in = net.in_conns(v).iter().map(|&c| pos[c as usize]).max();
        let first_out = net.out_conns(v).iter().map(|&c| pos[c as usize]).min();
        if let (Some(li), Some(fo)) = (last_in, first_out) {
            if li >= fo {
                return false;
            }
        }
    }
    true
}

/// The 2-optimal order from the proof of Theorem 1: take a topological
/// order of the neurons, then sort connections by (position of dst,
/// position of src). All connections ending in the same neuron are
/// consecutive ("intervals"), so each partial sum is produced start-to-
/// finish without interleaving — giving the ≤ 2·(W+N−I) guarantee.
pub fn two_optimal_order(net: &Ffnn) -> ConnOrder {
    let topo = net
        .neuron_topo_order()
        .expect("Ffnn construction guarantees acyclicity");
    order_by_neuron_positions(net, &topo)
}

/// Layer-after-layer order (the "standard" matrix-vector way): requires
/// layer metadata; connections sorted by (dst layer, dst id, src id).
/// On layered MLPs this equals [`two_optimal_order`] with the
/// layer-major neuron order — it is the paper's *Initial* configuration.
pub fn layerwise_order(net: &Ffnn) -> ConnOrder {
    let layer_of = net
        .layer_of()
        .expect("layerwise_order requires layer metadata");
    let mut neurons: Vec<NeuronId> = (0..net.n_neurons() as u32).collect();
    neurons.sort_by_key(|&v| (layer_of[v as usize], v));
    order_by_neuron_positions(net, &neurons)
}

/// Order connections by (pos(dst), pos(src)) for a given neuron order.
pub fn order_by_neuron_positions(net: &Ffnn, neuron_order: &[NeuronId]) -> ConnOrder {
    let mut pos = vec![0u32; net.n_neurons()];
    for (i, &v) in neuron_order.iter().enumerate() {
        pos[v as usize] = i as u32;
    }
    let mut perm: Vec<u32> = (0..net.n_conns() as u32).collect();
    perm.sort_by_key(|&ci| {
        let c = net.conn(ci as usize);
        (pos[c.dst as usize], pos[c.src as usize])
    });
    ConnOrder { perm }
}

/// Derive a topological order of the *neurons* from a topological order of
/// the connections (used by Theorem 2's proof direction and by the
/// streaming compiler): neurons ordered by the position of their last
/// incoming connection; sources (inputs / bias-only neurons) come first,
/// ordered by first use.
pub fn neuron_order_from_conn_order(net: &Ffnn, order: &ConnOrder) -> Vec<NeuronId> {
    let pos = order.positions();
    let w = net.n_conns() as u32;
    let mut key: Vec<(u32, u32, NeuronId)> = (0..net.n_neurons() as u32)
        .map(|v| {
            let last_in = net.in_conns(v).iter().map(|&c| pos[c as usize]).max();
            match last_in {
                // Finished at its last incoming connection.
                Some(li) => (li + 1, 1, v),
                // Source: available from the start; order by first use.
                None => {
                    let first_use = net
                        .out_conns(v)
                        .iter()
                        .map(|&c| pos[c as usize])
                        .min()
                        .unwrap_or(w);
                    (first_use, 0, v)
                }
            }
        })
        .collect();
    key.sort_unstable();
    key.into_iter().map(|(_, _, v)| v).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_mlp, MlpSpec};
    use crate::ffnn::graph::{Conn, NeuronKind};
    use crate::util::rng::Pcg64;

    fn diamond() -> Ffnn {
        Ffnn::new(
            vec![
                NeuronKind::Input,
                NeuronKind::Input,
                NeuronKind::Hidden,
                NeuronKind::Output,
            ],
            vec![1.0, 2.0, 0.5, -0.5],
            vec![
                Conn { src: 0, dst: 2, weight: 1.0 },
                Conn { src: 1, dst: 2, weight: 2.0 },
                Conn { src: 2, dst: 3, weight: 3.0 },
                Conn { src: 0, dst: 3, weight: 4.0 },
            ],
        )
        .unwrap()
    }

    #[test]
    fn identity_on_diamond_is_topological() {
        let net = diamond();
        assert!(ConnOrder::identity(4).is_topological(&net));
    }

    #[test]
    fn non_topological_detected() {
        let net = diamond();
        // Putting conn 2 (2->3) before conn 0 (0->2) violates topology.
        let order = ConnOrder::from_perm(vec![2, 0, 1, 3]);
        assert!(!order.is_topological(&net));
    }

    #[test]
    fn non_permutation_detected() {
        let net = diamond();
        assert!(!ConnOrder::from_perm(vec![0, 0, 1, 2]).is_topological(&net));
        assert!(!ConnOrder::from_perm(vec![0, 1]).is_topological(&net));
    }

    #[test]
    fn two_optimal_is_topological_and_interval() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(4, 30, 0.2), &mut rng);
        let order = two_optimal_order(&net);
        assert!(order.is_topological(&net));
        // Interval property: connections with the same dst are consecutive.
        let mut seen_dst: Vec<bool> = vec![false; net.n_neurons()];
        let mut prev_dst = u32::MAX;
        for &ci in order.as_slice() {
            let dst = net.conn(ci as usize).dst;
            if dst != prev_dst {
                assert!(!seen_dst[dst as usize], "dst {dst} interval split");
                seen_dst[dst as usize] = true;
                prev_dst = dst;
            }
        }
    }

    #[test]
    fn layerwise_is_topological() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_mlp(&MlpSpec::new(5, 20, 0.3), &mut rng);
        let order = layerwise_order(&net);
        assert!(order.is_topological(&net));
        // Layer-major: dst layers must be non-decreasing.
        let layer_of = net.layer_of().unwrap();
        let mut prev = 0;
        for &ci in order.as_slice() {
            let l = layer_of[net.conn(ci as usize).dst as usize];
            assert!(l >= prev);
            prev = l;
        }
    }

    #[test]
    fn positions_inverse() {
        let order = ConnOrder::from_perm(vec![2, 0, 3, 1]);
        let pos = order.positions();
        for (k, &ci) in order.as_slice().iter().enumerate() {
            assert_eq!(pos[ci as usize] as usize, k);
        }
    }

    #[test]
    fn neuron_order_from_conn_order_is_topological() {
        let net = diamond();
        let order = two_optimal_order(&net);
        let norder = neuron_order_from_conn_order(&net, &order);
        let mut pos = vec![0usize; net.n_neurons()];
        for (i, &v) in norder.iter().enumerate() {
            pos[v as usize] = i;
        }
        for c in net.conns() {
            assert!(
                pos[c.src as usize] < pos[c.dst as usize],
                "neuron order must respect edges"
            );
        }
    }
}
