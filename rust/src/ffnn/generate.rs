//! Random sparse MLP generation — the exact procedure of the paper's
//! Appendix A.
//!
//! "For each non-output neuron, we determine how many outgoing connections
//! it has, by drawing uniformly at random an integer k between 1 and
//! max(1, ⌈2·p·(#neurons in the next layer) − 1⌉). Then, we connect this
//! neuron to k randomly chosen neurons of the next layer." k ≥ 1 keeps the
//! FFNN connected and makes the single output neuron reachable from every
//! neuron of the last hidden layer.

use super::graph::{Conn, Ffnn, NeuronKind};
use crate::util::rng::Pcg64;

/// Specification for the paper's random MLPs: `depth` layers of `width`
/// neurons each, plus one output neuron; target edge density `p`.
///
/// The paper's baseline (§VI.A.1): depth 4, width 500, p = 0.10.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MlpSpec {
    pub depth: usize,
    pub width: usize,
    pub density: f64,
    /// Size of the final layer (1 in all paper experiments).
    pub n_outputs: usize,
    /// Weight scale for the synthetic Gaussian weights.
    pub weight_scale: f32,
}

impl MlpSpec {
    pub fn new(depth: usize, width: usize, density: f64) -> MlpSpec {
        MlpSpec {
            depth,
            width,
            density,
            n_outputs: 1,
            weight_scale: 1.0,
        }
    }

    /// The paper's baseline configuration (Fig. 2): 4×500 @ 10%.
    pub fn paper_baseline() -> MlpSpec {
        MlpSpec::new(4, 500, 0.10)
    }
}

/// Generate a random sparse MLP per Appendix A.
pub fn random_mlp(spec: &MlpSpec, rng: &mut Pcg64) -> Ffnn {
    assert!(spec.depth >= 1, "need at least one layer");
    assert!(spec.width >= 1 && spec.n_outputs >= 1);
    assert!(
        spec.density > 0.0 && spec.density <= 1.0,
        "density must be in (0, 1], got {}",
        spec.density
    );

    // Layer sizes: `depth` hidden-ish layers of `width` plus the output layer.
    let mut sizes = vec![spec.width; spec.depth];
    sizes.push(spec.n_outputs);
    random_layered(&sizes, spec.density, spec.weight_scale, rng)
}

/// Generate a random layered FFNN with arbitrary per-layer sizes using the
/// Appendix-A sampling rule between consecutive layers.
pub fn random_layered(sizes: &[usize], density: f64, weight_scale: f32, rng: &mut Pcg64) -> Ffnn {
    assert!(sizes.len() >= 2, "need ≥ 2 layers");
    let n: usize = sizes.iter().sum();

    // Neuron ids: layer-major.
    let mut kinds = Vec::with_capacity(n);
    let mut layer_of = Vec::with_capacity(n);
    let mut base = Vec::with_capacity(sizes.len());
    let mut acc = 0u32;
    for (li, &sz) in sizes.iter().enumerate() {
        base.push(acc);
        for _ in 0..sz {
            kinds.push(if li == 0 {
                NeuronKind::Input
            } else if li == sizes.len() - 1 {
                NeuronKind::Output
            } else {
                NeuronKind::Hidden
            });
            layer_of.push(li as u32);
            acc += 1;
        }
    }

    let initial: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * weight_scale).collect();

    let mut conns = Vec::new();
    for li in 0..sizes.len() - 1 {
        let next = sizes[li + 1];
        // Appendix A: k ~ U{1, ..., max(1, ceil(2·p·next − 1))}.
        let kmax = ((2.0 * density * next as f64).ceil() as i64 - 1).max(1) as u64;
        for s in 0..sizes[li] {
            let src = base[li] + s as u32;
            let k = rng.range_inclusive(1, kmax) as usize;
            let k = k.min(next);
            for t in rng.sample_distinct(next, k) {
                conns.push(Conn {
                    src,
                    dst: base[li + 1] + t as u32,
                    weight: rng.normal() as f32 * weight_scale,
                });
            }
        }
    }

    Ffnn::new(kinds, initial, conns)
        .expect("generator produces valid DAGs")
        .with_layers(layer_of)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_spec_matches_paper() {
        let s = MlpSpec::paper_baseline();
        assert_eq!((s.depth, s.width), (4, 500));
        assert!((s.density - 0.10).abs() < 1e-12);
    }

    #[test]
    fn shape_and_kinds() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(3, 50, 0.2), &mut rng);
        assert_eq!(net.n_neurons(), 3 * 50 + 1);
        assert_eq!(net.n_inputs(), 50);
        assert_eq!(net.n_outputs(), 1);
        assert_eq!(net.n_layers(), Some(4));
    }

    #[test]
    fn every_non_output_has_outgoing() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_mlp(&MlpSpec::new(4, 40, 0.1), &mut rng);
        for v in 0..net.n_neurons() as u32 {
            if net.kind(v) != NeuronKind::Output {
                assert!(net.out_degree(v) >= 1, "neuron {v} must have out-degree ≥ 1");
            }
        }
    }

    #[test]
    fn output_connected_to_all_last_hidden() {
        // With a single output neuron, k≥1 forces every last-hidden neuron
        // to connect to it (the paper's remark).
        let mut rng = Pcg64::seed_from(3);
        let net = random_mlp(&MlpSpec::new(3, 30, 0.15), &mut rng);
        let out = net.output_ids()[0];
        assert_eq!(net.in_degree(out), 30);
    }

    #[test]
    fn density_close_to_target() {
        let mut rng = Pcg64::seed_from(4);
        for &p in &[0.05, 0.1, 0.3] {
            let net = random_mlp(&MlpSpec::new(4, 200, p), &mut rng);
            // Expected k = (1 + ceil(2·p·w − 1))/2 ≈ p·w ⇒ density ≈ p.
            // The last (200→1) layer contributes 200 extra edges; exclude
            // tolerance generously.
            let d = net.density();
            assert!(
                (d - p).abs() < p * 0.25 + 0.01,
                "density {d} too far from {p}"
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let net1 = random_mlp(&MlpSpec::new(3, 20, 0.2), &mut Pcg64::seed_from(9));
        let net2 = random_mlp(&MlpSpec::new(3, 20, 0.2), &mut Pcg64::seed_from(9));
        assert_eq!(net1.n_conns(), net2.n_conns());
        assert_eq!(net1.conns(), net2.conns());
    }

    #[test]
    fn full_density_is_dense() {
        let mut rng = Pcg64::seed_from(5);
        let net = random_layered(&[10, 10], 1.0, 1.0, &mut rng);
        // kmax = ceil(2·1.0·10 − 1) = 19 > 10, capped at 10; expected k ≈
        // (1+10)/2 — not fully dense per edge, but every neuron has ≥ 1.
        assert!(net.n_conns() >= 10);
        assert!(net.n_conns() <= 100);
    }

    #[test]
    fn layered_arbitrary_sizes() {
        let mut rng = Pcg64::seed_from(6);
        let net = random_layered(&[8, 16, 4], 0.5, 1.0, &mut rng);
        assert_eq!(net.n_inputs(), 8);
        assert_eq!(net.n_outputs(), 4);
        assert!(net.is_connected() || net.n_conns() > 0);
    }
}
