//! FFNN bandwidth (paper §V, Corollary 1).
//!
//! The *bandwidth* of an FFNN is the smallest k such that some topological
//! order of the neurons places every connected pair at most k apart.
//! Corollary 1: with fast memory M ≥ k+2, inference needs no temporary
//! reads/writes (the net can be built by compact growth with a sliding
//! window of pebbles).
//!
//! Computing bandwidth exactly is NP-hard in general, so we provide:
//! * [`bandwidth_of_order`] — exact stretch of a given order,
//! * [`greedy_bandwidth_order`] — a Kahn-style heuristic that always picks
//!   the ready neuron whose earliest-placed predecessor is oldest,
//! * [`exact_bandwidth`] — branch-and-bound over topological orders for
//!   small nets (tests, codesign example).

use super::graph::{Ffnn, NeuronId};

/// Maximum distance between connected neurons under `order` (which must be
/// a topological order of the neurons).
pub fn bandwidth_of_order(net: &Ffnn, order: &[NeuronId]) -> usize {
    let mut pos = vec![0usize; net.n_neurons()];
    for (i, &v) in order.iter().enumerate() {
        pos[v as usize] = i;
    }
    net.conns()
        .iter()
        .map(|c| pos[c.dst as usize].saturating_sub(pos[c.src as usize]))
        .max()
        .unwrap_or(0)
}

/// Greedy topological order aiming for low bandwidth: repeatedly emit the
/// ready neuron (all predecessors placed) whose *earliest* predecessor
/// position is smallest — i.e., close the longest-open dependency first.
/// Sources are tie-broken by id for determinism.
pub fn greedy_bandwidth_order(net: &Ffnn) -> Vec<NeuronId> {
    let n = net.n_neurons();
    let mut remaining_in: Vec<u32> = (0..n).map(|v| net.in_degree(v as u32) as u32).collect();
    let mut pos = vec![usize::MAX; n];
    // Ready set as a simple vector scan: fine for generation-time use.
    let mut ready: Vec<NeuronId> = (0..n as u32)
        .filter(|&v| remaining_in[v as usize] == 0)
        .collect();
    let mut order = Vec::with_capacity(n);

    while let Some((ri, _)) = ready
        .iter()
        .enumerate()
        .map(|(ri, &v)| {
            let earliest_pred = net
                .in_conns(v)
                .iter()
                .map(|&ci| pos[net.conn(ci as usize).src as usize])
                .min()
                .unwrap_or(usize::MAX - 1);
            (ri, (earliest_pred, v))
        })
        .min_by_key(|&(_, key)| key)
    {
        let v = ready.swap_remove(ri);
        pos[v as usize] = order.len();
        order.push(v);
        for &ci in net.out_conns(v) {
            let d = net.conn(ci as usize).dst;
            remaining_in[d as usize] -= 1;
            if remaining_in[d as usize] == 0 {
                ready.push(d);
            }
        }
    }
    debug_assert_eq!(order.len(), n, "graph is a DAG");
    order
}

/// Exact minimum bandwidth by branch-and-bound over topological orders.
/// Exponential — only for small nets (≲ 16 neurons).
pub fn exact_bandwidth(net: &Ffnn) -> usize {
    let n = net.n_neurons();
    assert!(n <= 20, "exact_bandwidth is exponential; n={n} too large");
    let mut best = bandwidth_of_order(net, &greedy_bandwidth_order(net));
    let mut pos = vec![usize::MAX; n];
    let mut remaining_in: Vec<u32> = (0..n).map(|v| net.in_degree(v as u32) as u32).collect();

    fn dfs(
        net: &Ffnn,
        depth: usize,
        cur_bw: usize,
        best: &mut usize,
        pos: &mut Vec<usize>,
        remaining_in: &mut Vec<u32>,
    ) {
        let n = net.n_neurons();
        if cur_bw >= *best {
            return; // prune: cannot improve
        }
        if depth == n {
            *best = cur_bw;
            return;
        }
        for v in 0..n as u32 {
            if pos[v as usize] != usize::MAX || remaining_in[v as usize] != 0 {
                continue;
            }
            // Place v at `depth`.
            let stretch = net
                .in_conns(v)
                .iter()
                .map(|&ci| depth - pos[net.conn(ci as usize).src as usize])
                .max()
                .unwrap_or(0);
            let new_bw = cur_bw.max(stretch);
            if new_bw >= *best {
                continue;
            }
            pos[v as usize] = depth;
            for &ci in net.out_conns(v) {
                remaining_in[net.conn(ci as usize).dst as usize] -= 1;
            }
            dfs(net, depth + 1, new_bw, best, pos, remaining_in);
            for &ci in net.out_conns(v) {
                remaining_in[net.conn(ci as usize).dst as usize] += 1;
            }
            pos[v as usize] = usize::MAX;
        }
    }

    dfs(net, 0, 0, &mut best, &mut pos, &mut remaining_in);
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::extremal::prop2_chains;
    use crate::ffnn::graph::{Conn, NeuronKind};
    use crate::util::rng::Pcg64;

    fn path(n: usize) -> Ffnn {
        let mut kinds = vec![NeuronKind::Input];
        kinds.extend(std::iter::repeat(NeuronKind::Hidden).take(n - 2));
        kinds.push(NeuronKind::Output);
        let conns: Vec<Conn> = (0..n - 1)
            .map(|i| Conn {
                src: i as u32,
                dst: (i + 1) as u32,
                weight: 1.0,
            })
            .collect();
        Ffnn::new(kinds, vec![0.0; n], conns).unwrap()
    }

    #[test]
    fn path_has_bandwidth_one() {
        let net = path(6);
        let order = greedy_bandwidth_order(&net);
        assert_eq!(bandwidth_of_order(&net, &order), 1);
        assert_eq!(exact_bandwidth(&net), 1);
    }

    #[test]
    fn bandwidth_of_given_order() {
        let net = path(4);
        // Natural order: bandwidth 1. Reversed pairs: larger.
        assert_eq!(bandwidth_of_order(&net, &[0, 1, 2, 3]), 1);
        assert_eq!(bandwidth_of_order(&net, &[0, 2, 1, 3]), 2);
    }

    #[test]
    fn greedy_is_topological() {
        let net = prop2_chains(2, 3, &mut Pcg64::seed_from(1));
        let order = greedy_bandwidth_order(&net);
        let mut pos = vec![0usize; net.n_neurons()];
        for (i, &v) in order.iter().enumerate() {
            pos[v as usize] = i;
        }
        for c in net.conns() {
            assert!(pos[c.src as usize] < pos[c.dst as usize]);
        }
    }

    #[test]
    fn exact_never_worse_than_greedy() {
        let net = prop2_chains(1, 2, &mut Pcg64::seed_from(2)); // 6 neurons
        let greedy_bw = bandwidth_of_order(&net, &greedy_bandwidth_order(&net));
        let exact = exact_bandwidth(&net);
        assert!(exact <= greedy_bw);
        assert!(exact >= 1);
    }

    #[test]
    fn star_bandwidth_is_input_count() {
        // I inputs → 1 output: the output sits after all inputs; the first
        // input is I positions away, so bandwidth = I with any order.
        let net = crate::ffnn::extremal::lemma2_tree(5, &mut Pcg64::seed_from(3));
        assert_eq!(exact_bandwidth(&net), 5);
    }

    #[test]
    fn corollary1_bound_on_chains() {
        // Chain-after-chain order of the Prop-2 net has low bandwidth per
        // chain, but chains interleave through the shared input/output.
        let net = prop2_chains(2, 2, &mut Pcg64::seed_from(4));
        let bw = bandwidth_of_order(&net, &greedy_bandwidth_order(&net));
        // Shared output forces ≥ c+1 distance from the first chain's tail.
        assert!(bw >= 2);
    }
}
