//! Compact Growth (paper §V): the constructive characterization of FFNN
//! architectures admitting inference at the exact Theorem-1 lower bound
//! (N+W read-I/Os, S write-I/Os) for a given fast-memory size M.
//!
//! The construction is a pebble game over a *bag* (the fast memory):
//!
//! 1. with ≤ M−2 pebbles in the bag, add a gray (uncomputed) or black
//!    (computed) pebble = a new neuron,
//! 2. with a black `b` and a gray `g` in the bag, draw a connection
//!    `b → g` = one multiply-accumulate,
//! 3. turn gray → black = apply activation,
//! 4. remove a black pebble = delete from fast memory.
//!
//! [`PebbleBuilder`] exposes these four rules with their preconditions
//! checked; [`compact_growth`] runs the randomized generator of Appendix B
//! on top of it. The generator also returns the construction-order
//! [`ConnOrder`], which by Theorem 2 achieves the lower bound whenever the
//! simulated memory M ≥ M_g.

use super::graph::{Conn, Ffnn, NeuronId, NeuronKind};
use super::topo::ConnOrder;
use crate::util::rng::Pcg64;

/// Pebble colors (gray = partially computed, black = finished).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Color {
    Gray,
    Black,
}

/// Rule-violation errors from [`PebbleBuilder`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PebbleError {
    /// Rule 1 precondition: more than M−2 pebbles already in the bag.
    BagFull { in_bag: usize, m: usize },
    /// Rule 2: one endpoint is not in the bag or has the wrong color.
    BadConnection { reason: &'static str },
    /// Rule 3/4 applied to a pebble not in the bag / wrong color.
    BadPebble { reason: &'static str },
}

impl std::fmt::Display for PebbleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PebbleError::BagFull { in_bag, m } => {
                write!(f, "rule 1 violated: {in_bag} pebbles in bag, M={m} allows at most M-2")
            }
            PebbleError::BadConnection { reason } => write!(f, "rule 2 violated: {reason}"),
            PebbleError::BadPebble { reason } => write!(f, "rule 3/4 violated: {reason}"),
        }
    }
}
impl std::error::Error for PebbleError {}

/// Stateful compact-growth builder enforcing the four construction rules.
pub struct PebbleBuilder {
    m: usize,
    /// Color per created neuron, None once removed from the bag.
    in_bag: Vec<Option<Color>>,
    kinds: Vec<NeuronKind>,
    initial: Vec<f32>,
    conns: Vec<Conn>,
}

impl PebbleBuilder {
    /// Start an empty construction for memory size `m` (≥ 3).
    pub fn new(m: usize) -> PebbleBuilder {
        assert!(m >= 3, "the model requires M ≥ 3");
        PebbleBuilder {
            m,
            in_bag: Vec::new(),
            kinds: Vec::new(),
            initial: Vec::new(),
            conns: Vec::new(),
        }
    }

    pub fn bag_size(&self) -> usize {
        self.in_bag.iter().filter(|p| p.is_some()).count()
    }

    /// Neurons currently in the bag with the given color.
    pub fn bag_with(&self, color: Color) -> Vec<NeuronId> {
        self.in_bag
            .iter()
            .enumerate()
            .filter(|(_, c)| **c == Some(color))
            .map(|(i, _)| i as NeuronId)
            .collect()
    }

    /// Rule 1: add a neuron/pebble. Black pebbles model input neurons
    /// (already computed); gray pebbles model neurons under computation
    /// (their `initial` is the bias).
    pub fn add_neuron(&mut self, color: Color, initial: f32) -> Result<NeuronId, PebbleError> {
        let in_bag = self.bag_size();
        if in_bag > self.m - 2 {
            return Err(PebbleError::BagFull { in_bag, m: self.m });
        }
        let id = self.in_bag.len() as NeuronId;
        self.in_bag.push(Some(color));
        // Kind is provisional: inputs are black-added neurons with no
        // incoming connections; finalized in `finish()`.
        self.kinds.push(NeuronKind::Hidden);
        self.initial.push(initial);
        Ok(id)
    }

    /// Rule 2: draw a connection black → gray.
    pub fn connect(
        &mut self,
        src: NeuronId,
        dst: NeuronId,
        weight: f32,
    ) -> Result<(), PebbleError> {
        match self.in_bag.get(src as usize).copied().flatten() {
            Some(Color::Black) => {}
            Some(Color::Gray) => {
                return Err(PebbleError::BadConnection { reason: "source pebble is gray" })
            }
            None => return Err(PebbleError::BadConnection { reason: "source not in bag" }),
        }
        match self.in_bag.get(dst as usize).copied().flatten() {
            Some(Color::Gray) => {}
            Some(Color::Black) => {
                return Err(PebbleError::BadConnection { reason: "destination pebble is black" })
            }
            None => return Err(PebbleError::BadConnection { reason: "destination not in bag" }),
        }
        if src == dst {
            return Err(PebbleError::BadConnection { reason: "self-loop" });
        }
        if self
            .conns
            .iter()
            .any(|c| c.src == src && c.dst == dst)
        {
            return Err(PebbleError::BadConnection { reason: "duplicate connection" });
        }
        self.conns.push(Conn { src, dst, weight });
        Ok(())
    }

    /// Rule 3: finish a neuron (gray → black).
    pub fn blacken(&mut self, n: NeuronId) -> Result<(), PebbleError> {
        match self.in_bag.get_mut(n as usize) {
            Some(slot @ Some(Color::Gray)) => {
                *slot = Some(Color::Black);
                Ok(())
            }
            Some(Some(Color::Black)) => Err(PebbleError::BadPebble { reason: "already black" }),
            _ => Err(PebbleError::BadPebble { reason: "not in bag" }),
        }
    }

    /// Rule 4: remove a black pebble from the bag.
    pub fn remove(&mut self, n: NeuronId) -> Result<(), PebbleError> {
        match self.in_bag.get_mut(n as usize) {
            Some(slot @ Some(Color::Black)) => {
                *slot = None;
                Ok(())
            }
            Some(Some(Color::Gray)) => {
                Err(PebbleError::BadPebble { reason: "cannot remove a gray pebble" })
            }
            _ => Err(PebbleError::BadPebble { reason: "not in bag" }),
        }
    }

    /// Finalize: neurons with no incoming connections become inputs;
    /// `outputs` are marked as outputs. Returns the network and the
    /// construction connection order (which achieves the lower bound at
    /// memory size `m` by Theorem 2).
    pub fn finish(mut self, outputs: &[NeuronId]) -> (Ffnn, ConnOrder) {
        let n = self.kinds.len();
        let mut has_in = vec![false; n];
        for c in &self.conns {
            has_in[c.dst as usize] = true;
        }
        for i in 0..n {
            if !has_in[i] {
                self.kinds[i] = NeuronKind::Input;
            }
        }
        for &o in outputs {
            assert!(
                has_in[o as usize],
                "output neuron {o} has no incoming connections"
            );
            self.kinds[o as usize] = NeuronKind::Output;
        }
        let w = self.conns.len();
        let net = Ffnn::new(self.kinds, self.initial, self.conns)
            .expect("pebble rules guarantee a valid DAG");
        (net, ConnOrder::identity(w))
    }
}

/// Specification for the Appendix-B randomized compact-growth generator.
#[derive(Clone, Copy, Debug)]
pub struct CompactGrowthSpec {
    /// Design memory size M_g (the paper uses 100, 300, 500).
    pub m_g: usize,
    /// Number of growth iterations (paper: 1000).
    pub n_iter: usize,
    /// In-degree of each grown neuron (paper: 5).
    pub in_degree: usize,
}

impl CompactGrowthSpec {
    pub fn new(m_g: usize) -> CompactGrowthSpec {
        CompactGrowthSpec {
            m_g,
            n_iter: 1000,
            in_degree: 5,
        }
    }
}

/// Appendix-B generator: start with M_g−2 computed input neurons in the
/// bag; each iteration adds a neuron, draws `in_degree` incoming
/// connections from distinct random bag neurons, and removes the last of
/// those from the bag; finally one output neuron is connected from all
/// remaining bag neurons.
///
/// Returns `(net, order)` where `order` is the construction order — by
/// Theorem 2 inference in this order with M ≥ M_g uses exactly
/// N+W read-I/Os and S write-I/Os.
pub fn compact_growth(spec: &CompactGrowthSpec, rng: &mut Pcg64) -> (Ffnn, ConnOrder) {
    assert!(spec.m_g >= spec.in_degree + 2, "bag must fit in_degree sources");
    let mut b = PebbleBuilder::new(spec.m_g);

    // M_g − 2 readily computed input neurons.
    for _ in 0..spec.m_g - 2 {
        let v = rng.normal() as f32;
        b.add_neuron(Color::Black, v).expect("bag has room");
    }

    for _ in 0..spec.n_iter {
        let bias = rng.normal() as f32;
        let g = b.add_neuron(Color::Gray, bias).expect("rule 1 holds by invariant");
        // Choose in_degree distinct black sources currently in the bag.
        let blacks = b.bag_with(Color::Black);
        debug_assert!(blacks.len() >= spec.in_degree);
        let picks = rng.sample_distinct(blacks.len(), spec.in_degree);
        for &pi in &picks {
            let w = rng.normal() as f32;
            b.connect(blacks[pi], g, w).expect("rule 2 holds");
        }
        b.blacken(g).expect("rule 3 holds");
        // Remove the last of the chosen sources from the bag.
        let last = blacks[*picks.last().unwrap()];
        b.remove(last).expect("rule 4 holds");
    }

    // Output neuron fed by every remaining bag neuron except itself.
    let bias = rng.normal() as f32;
    let out = b.add_neuron(Color::Gray, bias).expect("rule 1 holds");
    let blacks = b.bag_with(Color::Black);
    for s in blacks {
        let w = rng.normal() as f32;
        b.connect(s, out, w).expect("rule 2 holds");
    }
    b.blacken(out).expect("rule 3 holds");

    b.finish(&[out])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_enforces_rule1() {
        let mut b = PebbleBuilder::new(4); // ≤ M−2 = 2 pebbles before an add
        b.add_neuron(Color::Black, 0.0).unwrap();
        b.add_neuron(Color::Black, 0.0).unwrap();
        b.add_neuron(Color::Gray, 0.0).unwrap(); // bag had 2 = M−2: allowed
        let e = b.add_neuron(Color::Gray, 0.0).unwrap_err();
        assert!(matches!(e, PebbleError::BagFull { in_bag: 3, m: 4 }));
    }

    #[test]
    fn builder_enforces_rule2_colors() {
        let mut b = PebbleBuilder::new(5);
        let black = b.add_neuron(Color::Black, 0.0).unwrap();
        let gray = b.add_neuron(Color::Gray, 0.0).unwrap();
        // gray → gray rejected
        assert!(b.connect(gray, gray, 1.0).is_err());
        // black → black rejected
        let black2 = b.add_neuron(Color::Black, 0.0).unwrap();
        assert!(b.connect(black, black2, 1.0).is_err());
        // black → gray ok
        b.connect(black, gray, 1.0).unwrap();
        // duplicate rejected
        assert!(b.connect(black, gray, 2.0).is_err());
    }

    #[test]
    fn builder_remove_and_blacken() {
        let mut b = PebbleBuilder::new(5);
        let g = b.add_neuron(Color::Gray, 0.0).unwrap();
        assert!(b.remove(g).is_err(), "gray cannot be removed");
        b.blacken(g).unwrap();
        assert!(b.blacken(g).is_err(), "already black");
        b.remove(g).unwrap();
        assert!(b.remove(g).is_err(), "not in bag anymore");
        assert_eq!(b.bag_size(), 0);
    }

    #[test]
    fn removed_pebble_cannot_connect() {
        let mut b = PebbleBuilder::new(5);
        let black = b.add_neuron(Color::Black, 0.0).unwrap();
        b.remove(black).unwrap();
        let gray = b.add_neuron(Color::Gray, 0.0).unwrap();
        assert!(b.connect(black, gray, 1.0).is_err());
    }

    #[test]
    fn generator_shape_matches_appendix_b() {
        let spec = CompactGrowthSpec { m_g: 100, n_iter: 1000, in_degree: 5 };
        let (net, order) = compact_growth(&spec, &mut Pcg64::seed_from(1));
        // N = (M_g − 2) initial + 1000 grown + 1 output.
        assert_eq!(net.n_neurons(), 98 + 1000 + 1);
        assert_eq!(net.n_inputs(), 98);
        assert_eq!(net.n_outputs(), 1);
        // W = 5 per iteration + |bag| into the output. Bag stays at M_g−2
        // through the loop, so the output has M_g−2 incoming connections.
        assert_eq!(net.n_conns(), 5 * 1000 + 98);
        assert!(order.is_topological(&net));
        assert!(net.is_connected());
    }

    #[test]
    fn generator_deterministic() {
        let spec = CompactGrowthSpec { m_g: 50, n_iter: 100, in_degree: 5 };
        let (a, _) = compact_growth(&spec, &mut Pcg64::seed_from(7));
        let (b, _) = compact_growth(&spec, &mut Pcg64::seed_from(7));
        assert_eq!(a.conns(), b.conns());
    }

    #[test]
    fn grown_neurons_have_requested_in_degree() {
        let spec = CompactGrowthSpec { m_g: 30, n_iter: 50, in_degree: 5 };
        let (net, _) = compact_growth(&spec, &mut Pcg64::seed_from(3));
        // Neurons 28..78 are the grown ones.
        for v in 28..78u32 {
            assert_eq!(net.in_degree(v), 5, "neuron {v}");
        }
    }
}
