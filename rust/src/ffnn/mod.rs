//! Sparse feed-forward neural networks as weighted DAGs (paper §II).
//!
//! An FFNN is a list of weighted connections `(i, j, w_ij)` over neurons
//! that each carry one extra value: the input value for input neurons, the
//! bias for everything else. No weight sharing, arbitrary DAG topology
//! (skip connections allowed) — exactly the model of the paper.

pub mod bandwidth;
pub mod bert;
pub mod compact_growth;
pub mod extremal;
pub mod generate;
pub mod graph;
pub mod serde;
pub mod topo;
