//! FFNN ⇄ JSON serialization: network files under `configs/`/`results/`
//! and the interchange format consumed by the Python AOT path (model
//! shapes + ELL packing parameters are derived from these files).

use super::graph::{Conn, Ffnn, NeuronKind};
use super::topo::ConnOrder;
use crate::util::json::Json;
use std::path::Path;

/// Serialize a network (and optionally a connection order) to JSON.
pub fn net_to_json(net: &Ffnn, order: Option<&ConnOrder>) -> Json {
    let kinds: Vec<Json> = net
        .kinds()
        .iter()
        .map(|k| {
            Json::Str(
                match k {
                    NeuronKind::Input => "input",
                    NeuronKind::Hidden => "hidden",
                    NeuronKind::Output => "output",
                }
                .to_string(),
            )
        })
        .collect();
    let initial: Vec<Json> = net.initials().iter().map(|&v| Json::Num(v as f64)).collect();
    let conns: Vec<Json> = net
        .conns()
        .iter()
        .map(|c| {
            Json::Arr(vec![
                Json::Num(c.src as f64),
                Json::Num(c.dst as f64),
                Json::Num(c.weight as f64),
            ])
        })
        .collect();
    let mut j = Json::obj()
        .set("format", "sparseflow-ffnn-v1")
        .set("kinds", Json::Arr(kinds))
        .set("initial", Json::Arr(initial))
        .set("conns", Json::Arr(conns));
    if let Some(layer_of) = net.layer_of() {
        j = j.set(
            "layer_of",
            Json::Arr(layer_of.iter().map(|&l| Json::Num(l as f64)).collect()),
        );
    }
    if let Some(order) = order {
        j = j.set(
            "order",
            Json::Arr(order.as_slice().iter().map(|&c| Json::Num(c as f64)).collect()),
        );
    }
    j
}

/// Deserialize a network (+ optional stored order).
pub fn net_from_json(j: &Json) -> anyhow::Result<(Ffnn, Option<ConnOrder>)> {
    anyhow::ensure!(
        j.get("format").and_then(Json::as_str) == Some("sparseflow-ffnn-v1"),
        "unknown or missing format tag"
    );
    let kinds: Vec<NeuronKind> = j
        .get("kinds")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing kinds"))?
        .iter()
        .map(|k| match k.as_str() {
            Some("input") => Ok(NeuronKind::Input),
            Some("hidden") => Ok(NeuronKind::Hidden),
            Some("output") => Ok(NeuronKind::Output),
            other => Err(anyhow::anyhow!("bad neuron kind {other:?}")),
        })
        .collect::<anyhow::Result<_>>()?;
    let initial: Vec<f32> = j
        .get("initial")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing initial"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow::anyhow!("bad initial")))
        .collect::<anyhow::Result<_>>()?;
    let conns: Vec<Conn> = j
        .get("conns")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing conns"))?
        .iter()
        .map(|c| {
            let a = c.as_arr().ok_or_else(|| anyhow::anyhow!("conn not an array"))?;
            anyhow::ensure!(a.len() == 3, "conn must be [src, dst, w]");
            Ok(Conn {
                src: a[0].as_u64().ok_or_else(|| anyhow::anyhow!("bad src"))? as u32,
                dst: a[1].as_u64().ok_or_else(|| anyhow::anyhow!("bad dst"))? as u32,
                weight: a[2].as_f64().ok_or_else(|| anyhow::anyhow!("bad weight"))? as f32,
            })
        })
        .collect::<anyhow::Result<_>>()?;

    let mut net = Ffnn::new(kinds, initial, conns).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(layers) = j.get("layer_of").and_then(Json::as_arr) {
        let layer_of: Vec<u32> = layers
            .iter()
            .map(|l| l.as_u64().map(|v| v as u32).ok_or_else(|| anyhow::anyhow!("bad layer")))
            .collect::<anyhow::Result<_>>()?;
        net = net.with_layers(layer_of);
    }
    let order = match j.get("order").and_then(Json::as_arr) {
        Some(arr) => {
            let perm: Vec<u32> = arr
                .iter()
                .map(|v| v.as_u64().map(|x| x as u32).ok_or_else(|| anyhow::anyhow!("bad order")))
                .collect::<anyhow::Result<_>>()?;
            let order = ConnOrder::from_perm(perm);
            anyhow::ensure!(order.is_topological(&net), "stored order is not topological");
            Some(order)
        }
        None => None,
    };
    Ok((net, order))
}

pub fn save_net(net: &Ffnn, order: Option<&ConnOrder>, path: &Path) -> anyhow::Result<()> {
    net_to_json(net, order)
        .to_file(path)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

pub fn load_net(path: &Path) -> anyhow::Result<(Ffnn, Option<ConnOrder>)> {
    let j = Json::from_file(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    net_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_mlp, MlpSpec};
    use crate::ffnn::topo::two_optimal_order;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(3, 12, 0.3), &mut rng);
        let order = two_optimal_order(&net);
        let j = net_to_json(&net, Some(&order));
        let (net2, order2) = net_from_json(&j).unwrap();
        assert_eq!(net.conns(), net2.conns());
        assert_eq!(net.kinds(), net2.kinds());
        assert_eq!(net.layer_of(), net2.layer_of());
        assert_eq!(order2.unwrap().as_slice(), order.as_slice());
    }

    #[test]
    fn roundtrip_via_file() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_mlp(&MlpSpec::new(2, 6, 0.5), &mut rng);
        let dir = std::env::temp_dir().join("sparseflow-serde-test");
        let path = dir.join("net.json");
        save_net(&net, None, &path).unwrap();
        let (net2, order) = load_net(&path).unwrap();
        assert_eq!(net.conns(), net2.conns());
        assert!(order.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::obj().set("format", "bogus");
        assert!(net_from_json(&j).is_err());
    }

    #[test]
    fn rejects_non_topological_order() {
        let mut rng = Pcg64::seed_from(3);
        let net = random_mlp(&MlpSpec::new(2, 4, 0.5), &mut rng);
        let mut j = net_to_json(&net, None);
        // Reversed identity is (generically) not topological.
        let rev: Vec<Json> = (0..net.n_conns() as u64).rev().map(Json::from).collect();
        j = j.set("order", Json::Arr(rev));
        assert!(net_from_json(&j).is_err());
    }
}
