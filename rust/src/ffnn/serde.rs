//! FFNN ⇄ JSON serialization: network files under `configs`/`results/`
//! and the interchange format consumed by the Python AOT path (model
//! shapes + ELL packing parameters are derived from these files).
//!
//! Also home of the **quantized artifact format**
//! (`sparseflow-quant-v1`): a [`QuantStreamProgram`]'s byte streams
//! round-trip through JSON (hex-encoded control/weight bytes, exact f32
//! group parameters) so a compressed model can be shipped without the
//! original network file.

use super::graph::{Conn, Ffnn, NeuronKind};
use super::topo::ConnOrder;
use crate::exec::quant::{QuantGroup, QuantParts, QuantStreamProgram};
use crate::util::json::Json;
use std::fmt::Write as _;
use std::path::Path;

/// Serialize a network (and optionally a connection order) to JSON.
pub fn net_to_json(net: &Ffnn, order: Option<&ConnOrder>) -> Json {
    let kinds: Vec<Json> = net
        .kinds()
        .iter()
        .map(|k| {
            Json::Str(
                match k {
                    NeuronKind::Input => "input",
                    NeuronKind::Hidden => "hidden",
                    NeuronKind::Output => "output",
                }
                .to_string(),
            )
        })
        .collect();
    let initial: Vec<Json> = net.initials().iter().map(|&v| Json::Num(v as f64)).collect();
    let conns: Vec<Json> = net
        .conns()
        .iter()
        .map(|c| {
            Json::Arr(vec![
                Json::Num(c.src as f64),
                Json::Num(c.dst as f64),
                Json::Num(c.weight as f64),
            ])
        })
        .collect();
    let mut j = Json::obj()
        .set("format", "sparseflow-ffnn-v1")
        .set("kinds", Json::Arr(kinds))
        .set("initial", Json::Arr(initial))
        .set("conns", Json::Arr(conns));
    if let Some(layer_of) = net.layer_of() {
        j = j.set(
            "layer_of",
            Json::Arr(layer_of.iter().map(|&l| Json::Num(l as f64)).collect()),
        );
    }
    if let Some(order) = order {
        j = j.set(
            "order",
            Json::Arr(order.as_slice().iter().map(|&c| Json::Num(c as f64)).collect()),
        );
    }
    j
}

/// Deserialize a network (+ optional stored order).
pub fn net_from_json(j: &Json) -> anyhow::Result<(Ffnn, Option<ConnOrder>)> {
    anyhow::ensure!(
        j.get("format").and_then(Json::as_str) == Some("sparseflow-ffnn-v1"),
        "unknown or missing format tag"
    );
    let kinds: Vec<NeuronKind> = j
        .get("kinds")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing kinds"))?
        .iter()
        .map(|k| match k.as_str() {
            Some("input") => Ok(NeuronKind::Input),
            Some("hidden") => Ok(NeuronKind::Hidden),
            Some("output") => Ok(NeuronKind::Output),
            other => Err(anyhow::anyhow!("bad neuron kind {other:?}")),
        })
        .collect::<anyhow::Result<_>>()?;
    let initial: Vec<f32> = j
        .get("initial")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing initial"))?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow::anyhow!("bad initial")))
        .collect::<anyhow::Result<_>>()?;
    let conns: Vec<Conn> = j
        .get("conns")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing conns"))?
        .iter()
        .map(|c| {
            let a = c.as_arr().ok_or_else(|| anyhow::anyhow!("conn not an array"))?;
            anyhow::ensure!(a.len() == 3, "conn must be [src, dst, w]");
            Ok(Conn {
                src: a[0].as_u64().ok_or_else(|| anyhow::anyhow!("bad src"))? as u32,
                dst: a[1].as_u64().ok_or_else(|| anyhow::anyhow!("bad dst"))? as u32,
                weight: a[2].as_f64().ok_or_else(|| anyhow::anyhow!("bad weight"))? as f32,
            })
        })
        .collect::<anyhow::Result<_>>()?;

    // The constructors validate (length mismatch, bad layer metadata,
    // bad endpoints, cycles, ...) and return errors — a corrupted file
    // is rejected, never a panic.
    let mut net = Ffnn::new(kinds, initial, conns).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(layers) = j.get("layer_of").and_then(Json::as_arr) {
        let layer_of: Vec<u32> = layers
            .iter()
            .map(|l| l.as_u64().map(|v| v as u32).ok_or_else(|| anyhow::anyhow!("bad layer")))
            .collect::<anyhow::Result<_>>()?;
        net = net.try_with_layers(layer_of).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    let order = match j.get("order").and_then(Json::as_arr) {
        Some(arr) => {
            let perm: Vec<u32> = arr
                .iter()
                .map(|v| v.as_u64().map(|x| x as u32).ok_or_else(|| anyhow::anyhow!("bad order")))
                .collect::<anyhow::Result<_>>()?;
            let order = ConnOrder::from_perm(perm);
            anyhow::ensure!(order.is_topological(&net), "stored order is not topological");
            Some(order)
        }
        None => None,
    };
    Ok((net, order))
}

#[deprecated(since = "0.6.0", note = "use crate::model::Model::save with Format::JsonV1")]
pub fn save_net(net: &Ffnn, order: Option<&ConnOrder>, path: &Path) -> anyhow::Result<()> {
    net_to_json(net, order)
        .to_file(path)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[deprecated(since = "0.6.0", note = "use crate::model::Model::load")]
pub fn load_net(path: &Path) -> anyhow::Result<(Ffnn, Option<ConnOrder>)> {
    let j = Json::from_file(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    net_from_json(&j)
}

// ---------------------------------------------------------------------
// Quantized artifact format (sparseflow-quant-v1)
// ---------------------------------------------------------------------

fn bytes_to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        write!(s, "{b:02x}").expect("write to String cannot fail");
    }
    s
}

fn hex_to_bytes(s: &str) -> anyhow::Result<Vec<u8>> {
    // from_str_radix alone is too lax (it accepts a leading '+').
    anyhow::ensure!(
        s.bytes().all(|b| b.is_ascii_hexdigit()),
        "hex string contains non-hex characters"
    );
    anyhow::ensure!(s.len() % 2 == 0, "odd hex-string length {}", s.len());
    (0..s.len())
        .step_by(2)
        .map(|i| {
            u8::from_str_radix(&s[i..i + 2], 16)
                .map_err(|e| anyhow::anyhow!("bad hex at byte {}: {e}", i / 2))
        })
        .collect()
}

fn u32s_to_json(ids: &[u32]) -> Json {
    Json::Arr(ids.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn u32s_from_json(j: &Json, key: &str) -> anyhow::Result<Vec<u32>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing {key}"))?
        .iter()
        .map(|v| {
            v.as_u64()
                .map(|x| x as u32)
                .ok_or_else(|| anyhow::anyhow!("bad entry in {key}"))
        })
        .collect()
}

/// Serialize a compressed program to the quantized artifact format.
/// Every field round-trips exactly: byte streams as hex, f32 values
/// through f64 JSON numbers (lossless widening).
pub fn quant_to_json(p: &QuantStreamProgram) -> Json {
    let qbytes: Vec<u8> = p.quantized_weights().iter().map(|&q| q as u8).collect();
    let groups: Vec<Json> = p
        .groups()
        .iter()
        .flat_map(|g| [Json::Num(g.scale as f64), Json::Num(g.zero_point as f64)])
        .collect();
    let biases: Vec<Json> = p.biases().iter().map(|&b| Json::Num(b as f64)).collect();
    Json::obj()
        .set("format", "sparseflow-quant-v1")
        .set("n_neurons", p.n_neurons())
        .set("group_size", crate::exec::quant::GROUP)
        .set("ctrl", bytes_to_hex(p.ctrl_bytes()))
        .set("qweights", bytes_to_hex(&qbytes))
        .set("groups", Json::Arr(groups))
        .set("biases", Json::Arr(biases))
        .set("hidden_sources", u32s_to_json(p.hidden_sources()))
        .set("input_ids", u32s_to_json(p.input_ids()))
        .set("output_ids", u32s_to_json(p.output_ids()))
}

/// Deserialize (and validate) a compressed program.
pub fn quant_from_json(j: &Json) -> anyhow::Result<QuantStreamProgram> {
    anyhow::ensure!(
        j.get("format").and_then(Json::as_str) == Some("sparseflow-quant-v1"),
        "unknown or missing quant format tag"
    );
    let group_size = j
        .get("group_size")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing group_size"))? as usize;
    anyhow::ensure!(
        group_size == crate::exec::quant::GROUP,
        "unsupported group size {group_size} (expected {})",
        crate::exec::quant::GROUP
    );
    let n_neurons = j
        .get("n_neurons")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing n_neurons"))? as usize;
    let ctrl = hex_to_bytes(
        j.get("ctrl")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing ctrl"))?,
    )?;
    let qweights: Vec<i8> = hex_to_bytes(
        j.get("qweights")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing qweights"))?,
    )?
    .into_iter()
    .map(|b| b as i8)
    .collect();
    let flat: Vec<f32> = j
        .get("groups")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing groups"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| anyhow::anyhow!("bad group value"))
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(flat.len() % 2 == 0, "groups must hold (scale, zero_point) pairs");
    let groups: Vec<QuantGroup> = flat
        .chunks_exact(2)
        .map(|pair| QuantGroup {
            scale: pair[0],
            zero_point: pair[1],
        })
        .collect();
    let biases: Vec<f32> = j
        .get("biases")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing biases"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|f| f as f32)
                .ok_or_else(|| anyhow::anyhow!("bad bias"))
        })
        .collect::<anyhow::Result<_>>()?;
    QuantStreamProgram::from_parts(QuantParts {
        ctrl,
        qweights,
        groups,
        biases,
        hidden_sources: u32s_from_json(j, "hidden_sources")?,
        input_ids: u32s_from_json(j, "input_ids")?,
        output_ids: u32s_from_json(j, "output_ids")?,
        n_neurons,
    })
}

#[deprecated(since = "0.6.0", note = "use crate::model::Model::save with Format::QuantJsonV1")]
pub fn save_quant(p: &QuantStreamProgram, path: &Path) -> anyhow::Result<()> {
    quant_to_json(p)
        .to_file(path)
        .map_err(|e| anyhow::anyhow!("write {}: {e}", path.display()))
}

#[deprecated(since = "0.6.0", note = "use crate::model::Model::load")]
pub fn load_quant(path: &Path) -> anyhow::Result<QuantStreamProgram> {
    let j = Json::from_file(path).map_err(|e| anyhow::anyhow!("{e}"))?;
    quant_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ffnn::generate::{random_mlp, MlpSpec};
    use crate::ffnn::topo::two_optimal_order;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Pcg64::seed_from(1);
        let net = random_mlp(&MlpSpec::new(3, 12, 0.3), &mut rng);
        let order = two_optimal_order(&net);
        let j = net_to_json(&net, Some(&order));
        let (net2, order2) = net_from_json(&j).unwrap();
        assert_eq!(net.conns(), net2.conns());
        assert_eq!(net.kinds(), net2.kinds());
        assert_eq!(net.layer_of(), net2.layer_of());
        assert_eq!(order2.unwrap().as_slice(), order.as_slice());
    }

    // The deprecated path-level shims must keep working until callers
    // are fully migrated to `model::Model`.
    #[test]
    #[allow(deprecated)]
    fn roundtrip_via_file() {
        let mut rng = Pcg64::seed_from(2);
        let net = random_mlp(&MlpSpec::new(2, 6, 0.5), &mut rng);
        let dir = std::env::temp_dir().join("sparseflow-serde-test");
        let path = dir.join("net.json");
        save_net(&net, None, &path).unwrap();
        let (net2, order) = load_net(&path).unwrap();
        assert_eq!(net.conns(), net2.conns());
        assert!(order.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::obj().set("format", "bogus");
        assert!(net_from_json(&j).is_err());
    }

    #[test]
    fn quant_roundtrip_is_bit_exact() {
        use crate::exec::batch::BatchMatrix;
        use crate::exec::quant::{QuantStreamEngine, QuantStreamProgram};
        use crate::exec::Engine;

        let mut rng = Pcg64::seed_from(11);
        let net = random_mlp(&MlpSpec::new(3, 14, 0.4), &mut rng);
        let order = two_optimal_order(&net);
        let program = QuantStreamProgram::compress(&net, &order);
        let j = quant_to_json(&program);
        let back = quant_from_json(&j).unwrap();
        assert_eq!(back, program, "quant artifact must round-trip exactly");

        // Identical programs produce identical outputs.
        let x = BatchMatrix::random(net.n_inputs(), 4, &mut rng);
        let a = QuantStreamEngine::from_program(program).infer(&x);
        let b = QuantStreamEngine::from_program(back).infer(&x);
        assert_eq!(a, b);
    }

    #[test]
    #[allow(deprecated)]
    fn quant_roundtrip_via_file_and_rejections() {
        use crate::exec::quant::QuantStreamProgram;

        let mut rng = Pcg64::seed_from(12);
        let net = random_mlp(&MlpSpec::new(2, 8, 0.5), &mut rng);
        let program = QuantStreamProgram::compress(&net, &two_optimal_order(&net));
        let dir = std::env::temp_dir().join("sparseflow-quant-serde-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.quant.json");
        save_quant(&program, &path).unwrap();
        assert_eq!(load_quant(&path).unwrap(), program);
        std::fs::remove_dir_all(&dir).ok();

        // Wrong format tag.
        assert!(quant_from_json(&Json::obj().set("format", "bogus")).is_err());
        // Corrupt control stream hex.
        let mut j = quant_to_json(&program);
        j = j.set("ctrl", "zz");
        assert!(quant_from_json(&j).is_err());
        // Truncated weights (record/weight count mismatch).
        let mut j = quant_to_json(&program);
        j = j.set("qweights", "00");
        assert!(quant_from_json(&j).is_err());
    }

    #[test]
    fn hex_helpers_roundtrip() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        let hex = bytes_to_hex(&bytes);
        assert_eq!(hex.len(), 512);
        assert_eq!(hex_to_bytes(&hex).unwrap(), bytes);
        assert!(hex_to_bytes("abc").is_err(), "odd length");
        assert!(hex_to_bytes("gg").is_err(), "non-hex digits");
        assert!(hex_to_bytes("+1").is_err(), "sign characters are not hex");
    }

    #[test]
    fn rejects_non_topological_order() {
        let mut rng = Pcg64::seed_from(3);
        let net = random_mlp(&MlpSpec::new(2, 4, 0.5), &mut rng);
        let mut j = net_to_json(&net, None);
        // Reversed identity is (generically) not topological.
        let rev: Vec<Json> = (0..net.n_conns() as u64).rev().map(Json::from).collect();
        j = j.set("order", Json::Arr(rev));
        assert!(net_from_json(&j).is_err());
    }
}
