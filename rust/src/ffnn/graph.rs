//! The FFNN graph representation.
//!
//! Neurons are dense ids `0..N`. Connections are stored once in a flat
//! `Vec<Conn>`; adjacency (incoming / outgoing connection lists in CSR
//! form) is derived on construction and kept immutable afterwards —
//! reordering operates on *permutations of connection indices*
//! ([`crate::ffnn::topo::ConnOrder`]), never on the graph itself.

pub type NeuronId = u32;

/// A weighted connection `src → dst`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Conn {
    pub src: NeuronId,
    pub dst: NeuronId,
    pub weight: f32,
}

/// Role of a neuron in the inference problem.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NeuronKind {
    /// Carries an input value; never has incoming connections.
    Input,
    Hidden,
    /// Its final value must be written to slow memory.
    Output,
}

/// An immutable sparse FFNN.
#[derive(Clone, Debug)]
pub struct Ffnn {
    conns: Vec<Conn>,
    kinds: Vec<NeuronKind>,
    /// Input value for inputs, bias for hidden/output neurons.
    initial: Vec<f32>,
    /// CSR: for each neuron, indices into `conns` of incoming connections.
    in_off: Vec<u32>,
    in_idx: Vec<u32>,
    /// CSR: outgoing connection indices.
    out_off: Vec<u32>,
    out_idx: Vec<u32>,
    /// Optional layered structure (layer id per neuron) for MLP-style nets.
    layer_of: Option<Vec<u32>>,
}

/// Construction-time validation failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    /// `kinds` and `initial` disagree on the neuron count.
    LengthMismatch { kinds: usize, initial: usize },
    /// Layer metadata does not cover every neuron
    /// ([`Ffnn::try_with_layers`]).
    LayerLengthMismatch { layers: usize, neurons: usize },
    /// A connection does not cross strictly increasing layers
    /// ([`Ffnn::try_with_layers`]).
    NonIncreasingLayers { conn: usize },
    /// A connection endpoint is out of range.
    BadEndpoint { conn: usize },
    /// An input neuron has incoming connections.
    InputWithIncoming { neuron: NeuronId },
    /// The connection graph has a directed cycle.
    Cyclic,
    /// Self-loop.
    SelfLoop { conn: usize },
    /// Duplicate connection (the model has independent parameters per
    /// connection, so parallel edges are disallowed).
    Duplicate { conn: usize },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GraphError::LengthMismatch { kinds, initial } => {
                write!(f, "kinds length {kinds} != initial length {initial}")
            }
            GraphError::LayerLengthMismatch { layers, neurons } => {
                write!(f, "layer_of length {layers} != {neurons} neurons")
            }
            GraphError::NonIncreasingLayers { conn } => {
                write!(f, "connection {conn} does not cross strictly increasing layers")
            }
            GraphError::BadEndpoint { conn } => {
                write!(f, "connection {conn}: endpoint out of range")
            }
            GraphError::InputWithIncoming { neuron } => {
                write!(f, "input neuron {neuron} has incoming connections")
            }
            GraphError::Cyclic => write!(f, "connection graph is cyclic"),
            GraphError::SelfLoop { conn } => write!(f, "connection {conn} is a self-loop"),
            GraphError::Duplicate { conn } => {
                write!(f, "connection {conn} duplicates an earlier one")
            }
        }
    }
}
impl std::error::Error for GraphError {}

impl Ffnn {
    /// Build and validate an FFNN.
    ///
    /// `initial[i]` is the input value (inputs) or bias (non-inputs).
    pub fn new(
        kinds: Vec<NeuronKind>,
        initial: Vec<f32>,
        conns: Vec<Conn>,
    ) -> Result<Ffnn, GraphError> {
        if kinds.len() != initial.len() {
            // An error, not an assert: untrusted artifact loaders feed
            // this constructor and must be able to reject bad files.
            return Err(GraphError::LengthMismatch {
                kinds: kinds.len(),
                initial: initial.len(),
            });
        }
        let n = kinds.len();

        for (ci, c) in conns.iter().enumerate() {
            if c.src as usize >= n || c.dst as usize >= n {
                return Err(GraphError::BadEndpoint { conn: ci });
            }
            if c.src == c.dst {
                return Err(GraphError::SelfLoop { conn: ci });
            }
            if kinds[c.dst as usize] == NeuronKind::Input {
                return Err(GraphError::InputWithIncoming { neuron: c.dst });
            }
        }

        // CSR adjacency.
        let (in_off, in_idx) = csr(n, conns.iter().map(|c| c.dst));
        let (out_off, out_idx) = csr(n, conns.iter().map(|c| c.src));

        // Duplicate detection: per dst, check repeated src.
        for v in 0..n {
            let lo = in_off[v] as usize;
            let hi = in_off[v + 1] as usize;
            let mut srcs: Vec<NeuronId> =
                in_idx[lo..hi].iter().map(|&ci| conns[ci as usize].src).collect();
            srcs.sort_unstable();
            for w in srcs.windows(2) {
                if w[0] == w[1] {
                    // Find the later of the two duplicates for the report.
                    let dup = in_idx[lo..hi]
                        .iter()
                        .filter(|&&ci| conns[ci as usize].src == w[0])
                        .map(|&ci| ci as usize)
                        .max()
                        .unwrap();
                    return Err(GraphError::Duplicate { conn: dup });
                }
            }
        }

        let net = Ffnn {
            conns,
            kinds,
            initial,
            in_off,
            in_idx,
            out_off,
            out_idx,
            layer_of: None,
        };
        // Acyclicity via Kahn on neurons.
        if net.neuron_topo_order().is_none() {
            return Err(GraphError::Cyclic);
        }
        Ok(net)
    }

    /// Attach layer metadata (used by layered generators and the
    /// layer-wise engines). `layer_of[i]` must be consistent with edges
    /// (strictly increasing along every connection) — only
    /// debug-asserted here; untrusted inputs go through
    /// [`Ffnn::try_with_layers`].
    pub fn with_layers(mut self, layer_of: Vec<u32>) -> Ffnn {
        debug_assert_eq!(layer_of.len(), self.n_neurons());
        debug_assert!(self
            .conns
            .iter()
            .all(|c| layer_of[c.src as usize] < layer_of[c.dst as usize]));
        self.layer_of = Some(layer_of);
        self
    }

    /// Validating variant of [`Ffnn::with_layers`] for untrusted input
    /// (artifact loading): rejects inconsistent layer metadata with an
    /// error instead of a (debug-only) panic.
    pub fn try_with_layers(self, layer_of: Vec<u32>) -> Result<Ffnn, GraphError> {
        if layer_of.len() != self.n_neurons() {
            return Err(GraphError::LayerLengthMismatch {
                layers: layer_of.len(),
                neurons: self.n_neurons(),
            });
        }
        if let Some(conn) = self
            .conns
            .iter()
            .position(|c| layer_of[c.src as usize] >= layer_of[c.dst as usize])
        {
            return Err(GraphError::NonIncreasingLayers { conn });
        }
        Ok(self.with_layers(layer_of))
    }

    // ----- sizes (paper notation) ----------------------------------------

    /// `N`: number of neurons.
    pub fn n_neurons(&self) -> usize {
        self.kinds.len()
    }

    /// `W`: number of connections.
    pub fn n_conns(&self) -> usize {
        self.conns.len()
    }

    /// `I`: number of input neurons.
    pub fn n_inputs(&self) -> usize {
        self.kinds.iter().filter(|k| **k == NeuronKind::Input).count()
    }

    /// `S`: number of output neurons.
    pub fn n_outputs(&self) -> usize {
        self.kinds.iter().filter(|k| **k == NeuronKind::Output).count()
    }

    // ----- accessors ------------------------------------------------------

    pub fn conns(&self) -> &[Conn] {
        &self.conns
    }

    pub fn conn(&self, ci: usize) -> Conn {
        self.conns[ci]
    }

    pub fn kind(&self, n: NeuronId) -> NeuronKind {
        self.kinds[n as usize]
    }

    pub fn kinds(&self) -> &[NeuronKind] {
        &self.kinds
    }

    /// Input value (inputs) or bias (others).
    pub fn initial(&self, n: NeuronId) -> f32 {
        self.initial[n as usize]
    }

    pub fn initials(&self) -> &[f32] {
        &self.initial
    }

    pub fn set_initials(&mut self, values: Vec<f32>) {
        assert_eq!(values.len(), self.n_neurons());
        self.initial = values;
    }

    /// Scale every connection weight and initial value by `factor`
    /// (e.g. to normalize synthetic N(0, 1) nets to the unit-scale
    /// activations quantized inference assumes).
    pub fn scale_weights(&mut self, factor: f32) {
        for c in &mut self.conns {
            c.weight *= factor;
        }
        for b in &mut self.initial {
            *b *= factor;
        }
    }

    pub fn in_conns(&self, n: NeuronId) -> &[u32] {
        let lo = self.in_off[n as usize] as usize;
        let hi = self.in_off[n as usize + 1] as usize;
        &self.in_idx[lo..hi]
    }

    pub fn out_conns(&self, n: NeuronId) -> &[u32] {
        let lo = self.out_off[n as usize] as usize;
        let hi = self.out_off[n as usize + 1] as usize;
        &self.out_idx[lo..hi]
    }

    pub fn in_degree(&self, n: NeuronId) -> usize {
        self.in_conns(n).len()
    }

    pub fn out_degree(&self, n: NeuronId) -> usize {
        self.out_conns(n).len()
    }

    pub fn mean_in_degree(&self) -> f64 {
        let non_input = self.n_neurons() - self.n_inputs();
        if non_input == 0 {
            0.0
        } else {
            self.n_conns() as f64 / non_input as f64
        }
    }

    pub fn layer_of(&self) -> Option<&[u32]> {
        self.layer_of.as_deref()
    }

    /// Number of layers if layered.
    pub fn n_layers(&self) -> Option<usize> {
        self.layer_of
            .as_ref()
            .map(|l| l.iter().copied().max().map_or(0, |m| m as usize + 1))
    }

    /// Neuron ids grouped per layer (requires layer metadata).
    pub fn layers(&self) -> Option<Vec<Vec<NeuronId>>> {
        let layer_of = self.layer_of.as_ref()?;
        let n_layers = self.n_layers()?;
        let mut layers = vec![Vec::new(); n_layers];
        for (i, &l) in layer_of.iter().enumerate() {
            layers[l as usize].push(i as NeuronId);
        }
        Some(layers)
    }

    pub fn input_ids(&self) -> Vec<NeuronId> {
        self.ids_of(NeuronKind::Input)
    }

    pub fn output_ids(&self) -> Vec<NeuronId> {
        self.ids_of(NeuronKind::Output)
    }

    fn ids_of(&self, kind: NeuronKind) -> Vec<NeuronId> {
        self.kinds
            .iter()
            .enumerate()
            .filter(|(_, k)| **k == kind)
            .map(|(i, _)| i as NeuronId)
            .collect()
    }

    /// Edge density relative to a layered dense MLP with the same layer
    /// sizes (only meaningful for layered nets); otherwise vs N².
    pub fn density(&self) -> f64 {
        if let Some(layers) = self.layers() {
            let dense: usize = layers.windows(2).map(|w| w[0].len() * w[1].len()).sum();
            if dense == 0 {
                return 0.0;
            }
            self.n_conns() as f64 / dense as f64
        } else {
            self.n_conns() as f64 / (self.n_neurons() as f64).powi(2)
        }
    }

    // ----- topology -------------------------------------------------------

    /// Kahn topological order of neurons; `None` if cyclic.
    pub fn neuron_topo_order(&self) -> Option<Vec<NeuronId>> {
        let n = self.n_neurons();
        let mut indeg: Vec<u32> = (0..n).map(|i| self.in_degree(i as NeuronId) as u32).collect();
        let mut queue: Vec<NeuronId> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let v = queue[head];
            head += 1;
            order.push(v);
            for &ci in self.out_conns(v) {
                let d = self.conns[ci as usize].dst;
                indeg[d as usize] -= 1;
                if indeg[d as usize] == 0 {
                    queue.push(d);
                }
            }
        }
        (order.len() == n).then_some(order)
    }

    /// True if the *undirected* version of the graph is connected
    /// (isolated neurons make it disconnected). The paper's theorems
    /// assume connected FFNNs.
    pub fn is_connected(&self) -> bool {
        let n = self.n_neurons();
        if n == 0 {
            return true;
        }
        let mut seen = vec![false; n];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            let neighbors = self
                .out_conns(v)
                .iter()
                .map(|&ci| self.conns[ci as usize].dst)
                .chain(self.in_conns(v).iter().map(|&ci| self.conns[ci as usize].src));
            for u in neighbors {
                if !seen[u as usize] {
                    seen[u as usize] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == n
    }

    /// Remove neurons with no connections at all (pruning can isolate
    /// neurons; the paper's counts assume a connected network). Relabels
    /// ids compactly, preserving relative order; drops layer metadata
    /// remapping consistently.
    pub fn drop_isolated(&self) -> Ffnn {
        let keep: Vec<bool> = (0..self.n_neurons())
            .map(|i| self.in_degree(i as u32) > 0 || self.out_degree(i as u32) > 0)
            .collect();
        let mut remap = vec![u32::MAX; self.n_neurons()];
        let mut kinds = Vec::new();
        let mut initial = Vec::new();
        let mut layer_of = self.layer_of.as_ref().map(|_| Vec::new());
        for i in 0..self.n_neurons() {
            if keep[i] {
                remap[i] = kinds.len() as u32;
                kinds.push(self.kinds[i]);
                initial.push(self.initial[i]);
                if let (Some(lo), Some(src)) = (&mut layer_of, self.layer_of.as_ref()) {
                    lo.push(src[i]);
                }
            }
        }
        let conns: Vec<Conn> = self
            .conns
            .iter()
            .map(|c| Conn {
                src: remap[c.src as usize],
                dst: remap[c.dst as usize],
                weight: c.weight,
            })
            .collect();
        let net = Ffnn::new(kinds, initial, conns).expect("drop_isolated preserves validity");
        match layer_of {
            Some(lo) => net.with_layers(lo),
            None => net,
        }
    }

    /// Short human-readable description.
    pub fn describe(&self) -> String {
        format!(
            "FFNN: N={} (I={}, S={}), W={}, mean in-degree {:.2}{}",
            self.n_neurons(),
            self.n_inputs(),
            self.n_outputs(),
            self.n_conns(),
            self.mean_in_degree(),
            match self.n_layers() {
                Some(l) => format!(", {l} layers"),
                None => String::new(),
            }
        )
    }
}

/// Build CSR offsets/indices for `n` buckets from a key iterator over the
/// connection list (key = bucket of connection i).
fn csr(n: usize, keys: impl Iterator<Item = NeuronId> + Clone) -> (Vec<u32>, Vec<u32>) {
    let mut off = vec![0u32; n + 1];
    for k in keys.clone() {
        off[k as usize + 1] += 1;
    }
    for i in 0..n {
        off[i + 1] += off[i];
    }
    let mut cursor = off.clone();
    let total = off[n] as usize;
    let mut idx = vec![0u32; total];
    for (ci, k) in keys.enumerate() {
        idx[cursor[k as usize] as usize] = ci as u32;
        cursor[k as usize] += 1;
    }
    (off, idx)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The running example: 2 inputs, 1 hidden, 1 output, diamond shape.
    pub(crate) fn diamond() -> Ffnn {
        Ffnn::new(
            vec![
                NeuronKind::Input,
                NeuronKind::Input,
                NeuronKind::Hidden,
                NeuronKind::Output,
            ],
            vec![1.0, 2.0, 0.5, -0.5],
            vec![
                Conn { src: 0, dst: 2, weight: 1.0 },
                Conn { src: 1, dst: 2, weight: 2.0 },
                Conn { src: 2, dst: 3, weight: 3.0 },
                Conn { src: 0, dst: 3, weight: 4.0 }, // skip connection
            ],
        )
        .unwrap()
    }

    #[test]
    fn length_mismatch_is_an_error_not_a_panic() {
        let err = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Output],
            vec![0.0],
            vec![Conn { src: 0, dst: 1, weight: 1.0 }],
        )
        .unwrap_err();
        assert_eq!(err, GraphError::LengthMismatch { kinds: 2, initial: 1 });
    }

    #[test]
    fn try_with_layers_validates() {
        assert_eq!(
            diamond().try_with_layers(vec![0, 0]).unwrap_err(),
            GraphError::LayerLengthMismatch { layers: 2, neurons: 4 }
        );
        // Flat layers violate strict increase on the first connection.
        assert_eq!(
            diamond().try_with_layers(vec![0, 0, 0, 0]).unwrap_err(),
            GraphError::NonIncreasingLayers { conn: 0 }
        );
        // A consistent layering is accepted and attached.
        let net = diamond().try_with_layers(vec![0, 0, 1, 2]).unwrap();
        assert_eq!(net.n_layers(), Some(3));
    }

    #[test]
    fn sizes_match_paper_notation() {
        let net = diamond();
        assert_eq!(net.n_neurons(), 4); // N
        assert_eq!(net.n_conns(), 4); // W
        assert_eq!(net.n_inputs(), 2); // I
        assert_eq!(net.n_outputs(), 1); // S
    }

    #[test]
    fn adjacency_csr() {
        let net = diamond();
        assert_eq!(net.in_conns(2), &[0, 1]);
        assert_eq!(net.in_conns(3), &[2, 3]);
        assert_eq!(net.out_conns(0), &[0, 3]);
        assert_eq!(net.in_degree(0), 0);
        assert_eq!(net.out_degree(2), 1);
    }

    #[test]
    fn topo_order_valid() {
        let net = diamond();
        let order = net.neuron_topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (i, &v) in order.iter().enumerate() {
                p[v as usize] = i;
            }
            p
        };
        for c in net.conns() {
            assert!(pos[c.src as usize] < pos[c.dst as usize]);
        }
    }

    #[test]
    fn rejects_cycle() {
        let e = Ffnn::new(
            vec![NeuronKind::Hidden, NeuronKind::Output],
            vec![0.0, 0.0],
            vec![
                Conn { src: 0, dst: 1, weight: 1.0 },
                Conn { src: 1, dst: 0, weight: 1.0 },
            ],
        )
        .unwrap_err();
        assert_eq!(e, GraphError::Cyclic);
    }

    #[test]
    fn rejects_input_with_incoming() {
        let e = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Input],
            vec![0.0, 0.0],
            vec![Conn { src: 0, dst: 1, weight: 1.0 }],
        )
        .unwrap_err();
        assert_eq!(e, GraphError::InputWithIncoming { neuron: 1 });
    }

    #[test]
    fn rejects_self_loop_and_duplicate() {
        let e = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Output],
            vec![0.0, 0.0],
            vec![Conn { src: 1, dst: 1, weight: 1.0 }],
        )
        .unwrap_err();
        assert!(matches!(e, GraphError::SelfLoop { .. }));

        let e = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Output],
            vec![0.0, 0.0],
            vec![
                Conn { src: 0, dst: 1, weight: 1.0 },
                Conn { src: 0, dst: 1, weight: 2.0 },
            ],
        )
        .unwrap_err();
        assert!(matches!(e, GraphError::Duplicate { .. }));
    }

    #[test]
    fn rejects_bad_endpoint() {
        let e = Ffnn::new(
            vec![NeuronKind::Input],
            vec![0.0],
            vec![Conn { src: 0, dst: 5, weight: 1.0 }],
        )
        .unwrap_err();
        assert!(matches!(e, GraphError::BadEndpoint { .. }));
    }

    #[test]
    fn connectivity() {
        assert!(diamond().is_connected());
        let disconnected = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Output, NeuronKind::Hidden],
            vec![0.0; 3],
            vec![Conn { src: 0, dst: 1, weight: 1.0 }],
        )
        .unwrap();
        assert!(!disconnected.is_connected());
    }

    #[test]
    fn drop_isolated_compacts() {
        let net = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Hidden, NeuronKind::Output],
            vec![1.0, 9.0, 2.0],
            vec![Conn { src: 0, dst: 2, weight: 1.0 }],
        )
        .unwrap();
        let compact = net.drop_isolated();
        assert_eq!(compact.n_neurons(), 2);
        assert_eq!(compact.n_conns(), 1);
        assert_eq!(compact.initial(1), 2.0);
        assert!(compact.is_connected());
    }

    #[test]
    fn layers_metadata() {
        let net = diamond(); // not layered: skip connection crosses layers
        assert!(net.layer_of().is_none());
        let layered = Ffnn::new(
            vec![NeuronKind::Input, NeuronKind::Hidden, NeuronKind::Output],
            vec![0.0; 3],
            vec![
                Conn { src: 0, dst: 1, weight: 1.0 },
                Conn { src: 1, dst: 2, weight: 1.0 },
            ],
        )
        .unwrap()
        .with_layers(vec![0, 1, 2]);
        assert_eq!(layered.n_layers(), Some(3));
        assert_eq!(layered.layers().unwrap()[1], vec![1]);
        assert!((layered.density() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scale_weights_scales_conns_and_initials() {
        let mut net = diamond();
        let conns: Vec<Conn> = net.conns().to_vec();
        let initials: Vec<f32> = net.initials().to_vec();
        net.scale_weights(0.5);
        for (c, orig) in net.conns().iter().zip(&conns) {
            assert_eq!(c.weight, orig.weight * 0.5);
            assert_eq!((c.src, c.dst), (orig.src, orig.dst));
        }
        for (b, orig) in net.initials().iter().zip(&initials) {
            assert_eq!(*b, orig * 0.5);
        }
    }
}
