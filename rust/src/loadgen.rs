//! Deterministic load generator for the serving coordinator.
//!
//! EIE and SparseNN evaluate sparse-inference engines under end-to-end
//! serving load, not just kernel microbenchmarks; this module does the
//! same for the engine lineup behind the deadline-aware pipeline. Two
//! arrival processes, both seeded through [`crate::util::rng::Pcg64`] so
//! the *workload* (arrival schedule + request inputs) is exactly
//! reproducible run to run:
//!
//! * **closed loop** — `clients` concurrent clients, each issuing its
//!   next request the moment the previous one completes (throughput-
//!   bounded by the server; the classic saturation probe), and
//! * **open loop** — Poisson-like arrivals at a target QPS (exponential
//!   inter-arrival gaps), which keeps offering load even when the server
//!   falls behind — the regime where bounded queues and deadline
//!   shedding matter.
//!
//! Closed-loop clients speak the retry protocol: a shed reply
//! (queue-full or breaker-open) is retried up to [`MAX_RETRIES`] times
//! after sleeping the server's `retry_after_ms` hint, jittered through
//! the client's seeded RNG — so backoff schedules are reproducible under
//! a fixed seed. Deadline misses are not retried (the budget is spent).
//! The report tallies `retried` (backoff retries issued) and `degraded`
//! (served responses computed by a below-top ladder rung).
//!
//! Outcomes are tallied per request (served / shed / deadline-missed /
//! engine-faulted / error) and summarized with exact nearest-rank
//! percentiles of the end-to-end latency and its queue-wait component —
//! the numbers `sparseflow loadgen` prints per engine variant and
//! `benches/perf_serve.rs` publishes to `BENCH_PERF_SERVE.json`.
//!
//! For chaos runs (`--fault-plan`, [`crate::exec::faults`]) the report
//! also carries the server's `engine_faults` *counter delta* across the
//! run: a batch panic that the dispatcher recovers by re-dispatching the
//! batch individually still counts as an engine fault even though every
//! request in it was ultimately served — outcome counts alone would hide
//! the contained fault.

use crate::coordinator::request::{InferenceError, Response};
use crate::coordinator::ServerHandle;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Closed-loop retry budget per request: shed replies are retried at
/// most this many times before the shed is recorded as the outcome.
pub const MAX_RETRIES: u32 = 3;

/// Cap on one backoff sleep (ms): keeps seeded runs fast even when the
/// server's `retry_after_ms` hint is large (e.g. a long breaker
/// cooldown).
pub const MAX_BACKOFF_MS: u64 = 100;

/// A load spec that cannot be run. Returned (not panicked) so CLI
/// callers can print a clean error: `--qps 0` used to trip an
/// `assert!` inside the arrival-schedule generator.
#[derive(Clone, Debug, PartialEq)]
pub enum LoadGenError {
    /// Open-loop rate must be finite and > 0 (an exponential gap with
    /// rate 0 or NaN has no meaning).
    InvalidQps(f64),
    /// A run of zero requests measures nothing.
    ZeroRequests,
    /// The target model is not deployed on the server.
    UnknownModel(String),
}

impl std::fmt::Display for LoadGenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadGenError::InvalidQps(qps) => {
                write!(f, "open-loop arrivals need a finite qps > 0 (got {qps})")
            }
            LoadGenError::ZeroRequests => write!(f, "load run needs at least one request"),
            LoadGenError::UnknownModel(m) => write!(f, "loadgen: unknown model {m:?}"),
        }
    }
}

impl std::error::Error for LoadGenError {}

/// Arrival process of the synthetic workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// `clients` concurrent closed-loop clients (think time zero).
    Closed { clients: usize },
    /// Open-loop Poisson-like arrivals at `qps` requests/second.
    Open { qps: f64 },
}

impl Arrival {
    pub fn describe(&self) -> String {
        match self {
            Arrival::Closed { clients } => format!("closed-{clients}"),
            Arrival::Open { qps } => format!("open-{qps:.0}qps"),
        }
    }
}

/// One load-generation run: arrival process, request budget, seed, SLO.
#[derive(Clone, Copy, Debug)]
pub struct LoadSpec {
    pub arrival: Arrival,
    /// Total requests to issue.
    pub requests: usize,
    /// Workload seed: arrival schedule and request inputs derive from it.
    pub seed: u64,
    /// Per-request deadline budget handed to the server (None = no SLO).
    pub deadline: Option<Duration>,
    /// Wall-clock cap in seconds (0 = no cap): closed-loop clients stop
    /// issuing new requests once it elapses, and the open-loop scheduler
    /// stops at the first arrival offset past it (never sleeping
    /// beyond the cap). Lets CI run "1 second of load" regardless of
    /// machine speed.
    pub max_secs: f64,
}

impl LoadSpec {
    pub fn closed(clients: usize, requests: usize, seed: u64) -> LoadSpec {
        LoadSpec {
            arrival: Arrival::Closed { clients },
            requests,
            seed,
            deadline: None,
            max_secs: 0.0,
        }
    }

    pub fn open(qps: f64, requests: usize, seed: u64) -> LoadSpec {
        LoadSpec {
            arrival: Arrival::Open { qps },
            requests,
            seed,
            deadline: None,
            max_secs: 0.0,
        }
    }

    pub fn with_deadline(mut self, deadline: Option<Duration>) -> LoadSpec {
        self.deadline = deadline;
        self
    }

    pub fn with_max_secs(mut self, secs: f64) -> LoadSpec {
        self.max_secs = secs;
        self
    }
}

/// Deterministic input vector for request `i` of a seeded workload:
/// standard-normal entries from a per-request generator, so any request
/// can be regenerated in isolation (workers need no shared RNG state).
pub fn input_for(seed: u64, i: u64, n_inputs: usize) -> Vec<f32> {
    let mut rng = Pcg64::seed_from(seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    (0..n_inputs).map(|_| rng.normal() as f32).collect()
}

/// Deterministic open-loop arrival offsets (seconds from run start):
/// cumulative exponential gaps with rate `qps` — the Poisson process the
/// open-loop driver replays. Rejects non-finite or non-positive rates
/// (NaN/∞ would silently produce a garbage schedule; 0 would divide by
/// zero) instead of panicking.
pub fn open_arrivals(qps: f64, n: usize, seed: u64) -> Result<Vec<f64>, LoadGenError> {
    if !(qps.is_finite() && qps > 0.0) {
        return Err(LoadGenError::InvalidQps(qps));
    }
    let mut rng = Pcg64::seed_from(seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // f64() < 1.0 strictly, so the log argument is > 0.
        t += -(1.0 - rng.f64()).ln() / qps;
        out.push(t);
    }
    Ok(out)
}

/// Per-request outcome classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OutcomeKind {
    Served,
    Shed,
    DeadlineMiss,
    /// The engine panicked on this request even after individual
    /// re-dispatch ([`InferenceError::EngineFault`]).
    EngineFault,
    Error,
}

#[derive(Clone, Copy, Debug)]
struct Outcome {
    kind: OutcomeKind,
    latency_secs: f64,
    queue_wait_secs: f64,
    /// Served by a below-top degradation rung (`Response::degraded`).
    degraded: bool,
}

fn classify(res: Result<Response, InferenceError>) -> Outcome {
    match res {
        Ok(r) => Outcome {
            kind: OutcomeKind::Served,
            latency_secs: r.latency_secs,
            queue_wait_secs: r.queue_wait_secs,
            degraded: r.degraded,
        },
        Err(e) => Outcome {
            kind: match e {
                InferenceError::QueueFull { .. } => OutcomeKind::Shed,
                // Breaker-open sheds are load shedding too: the client
                // should back off, not treat it as a hard error.
                InferenceError::Unhealthy { .. } => OutcomeKind::Shed,
                InferenceError::DeadlineExceeded => OutcomeKind::DeadlineMiss,
                InferenceError::EngineFault { .. } => OutcomeKind::EngineFault,
                _ => OutcomeKind::Error,
            },
            latency_secs: 0.0,
            queue_wait_secs: 0.0,
            degraded: false,
        },
    }
}

/// Nearest-rank percentile summary in milliseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantilesMs {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl QuantilesMs {
    fn of_secs(samples: &[f64]) -> QuantilesMs {
        if samples.is_empty() {
            return QuantilesMs::default();
        }
        // Sort once and index the nearest ranks directly
        // (`util::timing::percentile` re-sorts per call — 6 sorts per
        // report would be wasted work on 100k-request runs). Same
        // nearest-rank definition.
        let mut ms: Vec<f64> = samples.iter().map(|&s| s * 1e3).collect();
        ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let nearest = |p: f64| {
            let rank = ((p / 100.0) * ms.len() as f64).ceil() as usize;
            ms[rank.saturating_sub(1).min(ms.len() - 1)]
        };
        QuantilesMs {
            p50: nearest(50.0),
            p95: nearest(95.0),
            p99: nearest(99.0),
            mean: ms.iter().sum::<f64>() / ms.len() as f64,
            max: *ms.last().expect("non-empty"),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("p50", self.p50)
            .set("p95", self.p95)
            .set("p99", self.p99)
            .set("mean", self.mean)
            .set("max", self.max)
    }
}

/// Result of one load run against one model/engine variant.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// Engine-variant label (e.g. "fused-f32-w4") or model name.
    pub label: String,
    /// Arrival-process description (e.g. "closed-8", "open-500qps").
    pub mode: String,
    pub seed: u64,
    /// Requests issued (attempted submissions).
    pub issued: usize,
    pub served: usize,
    pub shed: usize,
    pub deadline_misses: usize,
    /// Requests whose reply was [`InferenceError::EngineFault`] (the
    /// engine panicked even on individual re-dispatch).
    pub faulted: usize,
    pub errors: usize,
    /// Served requests answered by a below-top degradation rung
    /// (subset of `served`).
    pub degraded: usize,
    /// Backoff retries issued by closed-loop clients after shed replies
    /// (attempts beyond the first submission; 0 for open loop).
    pub retried: usize,
    /// Server-side `engine_faults` counter delta across the run: counts
    /// panicked engine *invocations*, including batch panics that were
    /// fully recovered by re-dispatch (and so appear as served
    /// outcomes). `faulted` ≤ fault *requests*; this is the injected /
    /// contained fault count.
    pub engine_faults: u64,
    pub elapsed_secs: f64,
    /// Served requests per second of wall-clock (the serving analogue of
    /// the benches' rows/s).
    pub throughput_rps: f64,
    /// End-to-end latency of served requests.
    pub latency_ms: QuantilesMs,
    /// Queue-wait component of served requests.
    pub queue_wait_ms: QuantilesMs,
}

impl LoadReport {
    fn from_outcomes(
        label: &str,
        mode: &str,
        seed: u64,
        outcomes: &[Outcome],
        retried: usize,
        elapsed_secs: f64,
    ) -> LoadReport {
        let count = |k: OutcomeKind| outcomes.iter().filter(|o| o.kind == k).count();
        let served: Vec<&Outcome> =
            outcomes.iter().filter(|o| o.kind == OutcomeKind::Served).collect();
        let lat: Vec<f64> = served.iter().map(|o| o.latency_secs).collect();
        let qw: Vec<f64> = served.iter().map(|o| o.queue_wait_secs).collect();
        LoadReport {
            label: label.to_string(),
            mode: mode.to_string(),
            seed,
            issued: outcomes.len(),
            served: served.len(),
            shed: count(OutcomeKind::Shed),
            deadline_misses: count(OutcomeKind::DeadlineMiss),
            faulted: count(OutcomeKind::EngineFault),
            errors: count(OutcomeKind::Error),
            degraded: served.iter().filter(|o| o.degraded).count(),
            retried,
            // Filled in by `run` from the server metrics delta; the
            // outcome list alone cannot see recovered batch panics.
            engine_faults: 0,
            elapsed_secs,
            throughput_rps: served.len() as f64 / elapsed_secs.max(1e-9),
            latency_ms: QuantilesMs::of_secs(&lat),
            queue_wait_ms: QuantilesMs::of_secs(&qw),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("label", self.label.as_str())
            .set("mode", self.mode.as_str())
            .set("seed", self.seed)
            .set("issued", self.issued)
            .set("served", self.served)
            .set("shed", self.shed)
            .set("deadline_misses", self.deadline_misses)
            .set("faulted", self.faulted)
            .set("errors", self.errors)
            .set("degraded", self.degraded)
            .set("retried", self.retried)
            .set("engine_faults", self.engine_faults)
            .set("elapsed_secs", self.elapsed_secs)
            .set("throughput_rps", self.throughput_rps)
            .set("latency_ms", self.latency_ms.to_json())
            .set("queue_wait_ms", self.queue_wait_ms.to_json())
    }

    /// One fixed-width table row (pair with [`LoadReport::table_header`]).
    pub fn table_row(&self) -> String {
        format!(
            "{:<18} {:<12} {:>8} {:>8} {:>6} {:>6} {:>6} {:>6} {:>6} \
             {:>10.0} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            self.label,
            self.mode,
            self.issued,
            self.served,
            self.shed,
            self.deadline_misses,
            self.engine_faults,
            self.degraded,
            self.retried,
            self.throughput_rps,
            self.latency_ms.p50,
            self.latency_ms.p99,
            self.queue_wait_ms.p50,
            self.queue_wait_ms.p99,
        )
    }

    pub fn table_header() -> String {
        format!(
            "{:<18} {:<12} {:>8} {:>8} {:>6} {:>6} {:>6} {:>6} {:>6} \
             {:>10} {:>9} {:>9} {:>9} {:>9}",
            "variant",
            "mode",
            "issued",
            "served",
            "shed",
            "miss",
            "fault",
            "degr",
            "retry",
            "rps",
            "lat p50",
            "lat p99",
            "qw p50",
            "qw p99",
        )
    }
}

/// Run one seeded load generation against a model behind `handle`.
///
/// Closed loop: `clients` worker threads share an atomic request
/// counter; each claims the next index, regenerates its deterministic
/// input, and blocks on the inference — the next request is only issued
/// once the previous completes. Open loop: a scheduler thread submits
/// request `i` at its precomputed arrival offset (sleeping between
/// arrivals, never spinning) and replies are collected afterwards, so
/// slow servers see the full offered load.
///
/// Errors instead of panicking on specs that cannot run: zero requests,
/// an unknown model, or (open loop) a non-finite or non-positive qps.
pub fn run(
    handle: &ServerHandle,
    model: &str,
    spec: &LoadSpec,
) -> Result<LoadReport, LoadGenError> {
    if spec.requests == 0 {
        return Err(LoadGenError::ZeroRequests);
    }
    let n_inputs = handle
        .n_inputs(model)
        .ok_or_else(|| LoadGenError::UnknownModel(model.to_string()))?;
    // Bracket the run with the server's engine-fault counter so the
    // report shows contained faults (recovered batch panics) that never
    // surface as request outcomes. The counter is server-global; run
    // variants against separate servers (as `sparseflow loadgen` does)
    // for exact per-variant attribution.
    let faults_before = engine_fault_count(handle);
    let mut report = match spec.arrival {
        Arrival::Closed { clients } => run_closed(handle, model, n_inputs, clients, spec),
        Arrival::Open { qps } => run_open(handle, model, n_inputs, qps, spec)?,
    };
    report.engine_faults = engine_fault_count(handle).saturating_sub(faults_before);
    Ok(report)
}

fn engine_fault_count(handle: &ServerHandle) -> u64 {
    handle
        .metrics_snapshot()
        .get("engine_faults")
        .and_then(Json::as_u64)
        .unwrap_or(0)
}

fn run_closed(
    handle: &ServerHandle,
    model: &str,
    n_inputs: usize,
    clients: usize,
    spec: &LoadSpec,
) -> LoadReport {
    let clients = clients.max(1);
    let next = AtomicUsize::new(0);
    let start = Instant::now();
    let cap = if spec.max_secs > 0.0 {
        Some(Duration::from_secs_f64(spec.max_secs))
    } else {
        None
    };
    let worker_ids: Vec<usize> = (0..clients).collect();
    let per_worker: Vec<(Vec<Outcome>, usize)> =
        crate::util::threadpool::par_map(clients, &worker_ids, |&w| {
            let mut mine = Vec::new();
            let mut retried = 0usize;
            // Per-client backoff RNG: jitter schedules are reproducible
            // under a fixed workload seed.
            let mut rng =
                Pcg64::seed_from(spec.seed ^ (w as u64).wrapping_mul(0xD134_2543_DE82_EF95));
            loop {
                if cap.is_some_and(|c| start.elapsed() >= c) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= spec.requests {
                    break;
                }
                let mut res = handle.infer_with_deadline(
                    model,
                    input_for(spec.seed, i as u64, n_inputs),
                    spec.deadline,
                );
                // Retry protocol: shed replies (queue-full / breaker)
                // back off for the server's hint — jittered ±50% so
                // clients don't re-arrive in lockstep — then resubmit.
                // Deadline misses are final: their budget is spent.
                let mut attempts = 0u32;
                while attempts < MAX_RETRIES
                    && matches!(
                        res,
                        Err(InferenceError::QueueFull { .. })
                            | Err(InferenceError::Unhealthy { .. })
                    )
                    && !cap.is_some_and(|c| start.elapsed() >= c)
                {
                    let base = handle.retry_after_ms(model).unwrap_or(1).clamp(1, MAX_BACKOFF_MS);
                    let jitter = 0.5 + rng.f64();
                    std::thread::sleep(Duration::from_secs_f64(base as f64 * jitter / 1e3));
                    attempts += 1;
                    retried += 1;
                    res = handle.infer_with_deadline(
                        model,
                        input_for(spec.seed, i as u64, n_inputs),
                        spec.deadline,
                    );
                }
                mine.push(classify(res));
            }
            (mine, retried)
        });
    let elapsed = start.elapsed().as_secs_f64();
    let retried: usize = per_worker.iter().map(|(_, r)| r).sum();
    let outcomes: Vec<Outcome> = per_worker.into_iter().flat_map(|(o, _)| o).collect();
    LoadReport::from_outcomes(
        model,
        &spec.arrival.describe(),
        spec.seed,
        &outcomes,
        retried,
        elapsed,
    )
}

fn run_open(
    handle: &ServerHandle,
    model: &str,
    n_inputs: usize,
    qps: f64,
    spec: &LoadSpec,
) -> Result<LoadReport, LoadGenError> {
    let arrivals = open_arrivals(qps, spec.requests, spec.seed)?;
    let start = Instant::now();
    let cap = if spec.max_secs > 0.0 {
        Some(Duration::from_secs_f64(spec.max_secs))
    } else {
        None
    };
    // Submit at the scheduled offsets; collect replies afterwards so a
    // backlogged server keeps receiving the offered load.
    let mut pending = Vec::with_capacity(arrivals.len());
    for (i, &at) in arrivals.iter().enumerate() {
        if cap.is_some_and(|c| start.elapsed() >= c) {
            break;
        }
        let due = Duration::from_secs_f64(at);
        // Arrival offsets are increasing, so once one lands past the cap
        // the run is over — never sleep beyond the cap (at 0.1 qps a
        // single exponential gap can dwarf a 1 s budget).
        if cap.is_some_and(|c| due >= c) {
            break;
        }
        let now = start.elapsed();
        if due > now {
            std::thread::sleep(due - now);
        }
        let input = input_for(spec.seed, i as u64, n_inputs);
        pending.push(handle.submit_with_deadline(model, input, spec.deadline));
    }
    let outcomes: Vec<Outcome> = pending
        .into_iter()
        .map(|sub| match sub {
            Ok(rx) => classify(rx.recv().unwrap_or(Err(InferenceError::ShuttingDown))),
            Err(e) => classify(Err(e)),
        })
        .collect();
    let elapsed = start.elapsed().as_secs_f64();
    Ok(LoadReport::from_outcomes(
        model,
        &spec.arrival.describe(),
        spec.seed,
        &outcomes,
        0,
        elapsed,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatchPolicy;
    use crate::coordinator::{AdmissionPolicy, ModelVariant, Router, Server, ServerConfig};
    use crate::exec::batch::BatchMatrix;
    use crate::exec::Engine;
    use std::sync::Arc;

    struct Echo;
    impl Engine for Echo {
        fn infer(&self, x: &BatchMatrix) -> BatchMatrix {
            x.clone()
        }
        fn name(&self) -> &'static str {
            "echo"
        }
        fn n_inputs(&self) -> usize {
            4
        }
        fn n_outputs(&self) -> usize {
            4
        }
    }

    struct SlowEcho(Duration);
    impl Engine for SlowEcho {
        fn infer(&self, x: &BatchMatrix) -> BatchMatrix {
            std::thread::sleep(self.0);
            x.clone()
        }
        fn name(&self) -> &'static str {
            "slow-echo"
        }
        fn n_inputs(&self) -> usize {
            4
        }
        fn n_outputs(&self) -> usize {
            4
        }
    }

    fn echo_server(config: ServerConfig) -> Server {
        let mut router = Router::new();
        router.register(ModelVariant::new("m", Arc::new(Echo)));
        Server::start(router, config)
    }

    #[test]
    fn workload_is_deterministic() {
        assert_eq!(input_for(7, 3, 6), input_for(7, 3, 6));
        assert_ne!(input_for(7, 3, 6), input_for(7, 4, 6), "per-request variation");
        assert_ne!(input_for(8, 3, 6), input_for(7, 3, 6), "per-seed variation");

        let a = open_arrivals(100.0, 50, 42).unwrap();
        let b = open_arrivals(100.0, 50, 42).unwrap();
        assert_eq!(a, b, "same seed, same schedule");
        assert!(a.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        // Mean gap ≈ 1/qps: the sum of 50 Exp(100) gaps concentrates
        // around 0.5 s; accept a wide deterministic-seed band.
        assert!(a[49] > 0.1 && a[49] < 2.0, "50 arrivals at 100 qps ended at {}", a[49]);
        assert_ne!(open_arrivals(100.0, 50, 43).unwrap(), a, "different seed, different schedule");
    }

    #[test]
    fn bad_specs_error_instead_of_panicking() {
        // qps <= 0 and non-finite rates are structured errors, not
        // assertion failures (NaN compares unequal to itself, so match
        // on the variant rather than the payload).
        assert_eq!(open_arrivals(0.0, 10, 1), Err(LoadGenError::InvalidQps(0.0)));
        assert_eq!(open_arrivals(-2.5, 10, 1), Err(LoadGenError::InvalidQps(-2.5)));
        assert!(matches!(
            open_arrivals(f64::NAN, 10, 1),
            Err(LoadGenError::InvalidQps(_))
        ));
        assert!(matches!(
            open_arrivals(f64::INFINITY, 10, 1),
            Err(LoadGenError::InvalidQps(_))
        ));

        let server = echo_server(ServerConfig::default());
        let h = server.handle();
        assert_eq!(
            run(&h, "m", &LoadSpec::open(0.0, 10, 1)).unwrap_err(),
            LoadGenError::InvalidQps(0.0)
        );
        assert_eq!(
            run(&h, "m", &LoadSpec::closed(2, 0, 1)).unwrap_err(),
            LoadGenError::ZeroRequests
        );
        assert_eq!(
            run(&h, "nope", &LoadSpec::closed(2, 4, 1)).unwrap_err(),
            LoadGenError::UnknownModel("nope".to_string())
        );
        // The error messages are CLI-grade.
        assert!(LoadGenError::InvalidQps(0.0).to_string().contains("qps"));
    }

    #[test]
    fn closed_loop_serves_all_requests() {
        let server = echo_server(ServerConfig::default());
        let h = server.handle();
        let spec = LoadSpec::closed(4, 60, 0xABC);
        let rep = run(&h, "m", &spec).unwrap();
        assert_eq!(rep.issued, 60);
        assert_eq!(rep.served, 60);
        assert_eq!((rep.shed, rep.deadline_misses, rep.errors), (0, 0, 0));
        assert_eq!((rep.degraded, rep.retried), (0, 0), "no ladder, nothing shed");
        assert!(rep.throughput_rps > 0.0);
        assert!(rep.latency_ms.p50 >= 0.0 && rep.latency_ms.p50 <= rep.latency_ms.p99);
        assert!(rep.queue_wait_ms.p99 <= rep.latency_ms.max + 1e-9);
        assert_eq!(rep.mode, "closed-4");
    }

    #[test]
    fn open_loop_offers_full_load() {
        let server = echo_server(ServerConfig::default());
        let h = server.handle();
        let spec = LoadSpec::open(2000.0, 40, 0xDEF);
        let rep = run(&h, "m", &spec).unwrap();
        assert_eq!(rep.issued, 40);
        assert_eq!(rep.served, 40);
        assert_eq!(rep.mode, "open-2000qps");
    }

    #[test]
    fn saturation_sheds_without_deadlock() {
        // A slow engine behind a tiny bounded queue, hammered by a fast
        // open loop: some requests must shed, the rest complete, and the
        // run terminates.
        let mut router = Router::new();
        router.register(ModelVariant::new("m", Arc::new(SlowEcho(Duration::from_millis(25)))));
        let server = Server::start(
            router,
            ServerConfig {
                batch: BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                admission: AdmissionPolicy { max_queue: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let h = server.handle();
        let spec = LoadSpec::open(2000.0, 80, 0x5A7);
        let rep = run(&h, "m", &spec).unwrap();
        assert_eq!(rep.issued, 80);
        assert!(rep.shed > 0, "bounded queue must shed under 2000 qps offered load");
        assert_eq!(
            rep.served + rep.shed + rep.deadline_misses + rep.faulted + rep.errors,
            80,
            "every issued request resolves to exactly one outcome"
        );
        assert!(rep.served > 0, "admitted requests still complete");
        let snap = h.metrics_snapshot();
        assert_eq!(snap.get("shed").unwrap().as_u64(), Some(rep.shed as u64));
    }

    #[test]
    fn deadline_misses_are_counted() {
        let server = echo_server(ServerConfig::default());
        let h = server.handle();
        let spec = LoadSpec::closed(2, 10, 1).with_deadline(Some(Duration::ZERO));
        let rep = run(&h, "m", &spec).unwrap();
        assert_eq!(rep.issued, 10);
        assert_eq!(rep.deadline_misses, 10, "zero budget misses everything");
        assert_eq!(rep.served, 0);
    }

    #[test]
    fn wall_clock_cap_stops_issuing() {
        let mut router = Router::new();
        router.register(ModelVariant::new("m", Arc::new(SlowEcho(Duration::from_millis(20)))));
        let server = Server::start(router, ServerConfig::default());
        let h = server.handle();
        // 10k requests would take ~3 minutes at 20 ms each; the 0.15 s
        // cap must cut the run short.
        let spec = LoadSpec::closed(2, 10_000, 2).with_max_secs(0.15);
        let start = Instant::now();
        let rep = run(&h, "m", &spec).unwrap();
        assert!(rep.issued < 10_000, "cap must stop issuance");
        assert!(start.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn report_serializes() {
        let server = echo_server(ServerConfig::default());
        let h = server.handle();
        let rep = run(&h, "m", &LoadSpec::closed(2, 8, 3)).unwrap();
        let j = rep.to_json();
        assert_eq!(j.get("served").unwrap().as_u64(), Some(8));
        assert_eq!(j.get("faulted").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("engine_faults").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("degraded").unwrap().as_u64(), Some(0));
        assert_eq!(j.get("retried").unwrap().as_u64(), Some(0));
        assert!(j.path(&["latency_ms", "p99"]).is_some());
        assert!(j.path(&["queue_wait_ms", "p50"]).is_some());
        assert!(LoadReport::table_header().contains("rps"));
        assert!(LoadReport::table_header().contains("fault"));
        assert!(LoadReport::table_header().contains("degr"));
        assert!(LoadReport::table_header().contains("retry"));
        assert!(rep.table_row().contains("closed-2"));
    }

    #[test]
    fn closed_loop_retries_shed_requests_with_backoff() {
        // Slow engine + tiny bounded queue + 8 closed-loop clients:
        // admission must shed some first attempts, and the retry
        // protocol turns most of them back into served outcomes.
        let mut router = Router::new();
        router.register(ModelVariant::new("m", Arc::new(SlowEcho(Duration::from_millis(5)))));
        let server = Server::start(
            router,
            ServerConfig {
                batch: BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                admission: AdmissionPolicy { max_queue: 2, ..Default::default() },
                ..Default::default()
            },
        );
        let h = server.handle();
        let rep = run(&h, "m", &LoadSpec::closed(8, 64, 0xBAC)).unwrap();
        assert_eq!(rep.issued, 64);
        assert!(rep.retried > 0, "shed replies must trigger backoff retries");
        assert_eq!(
            rep.served + rep.shed + rep.deadline_misses + rep.faulted + rep.errors,
            64,
            "retries collapse into one outcome per issued request"
        );
        assert!(
            rep.served > rep.shed,
            "backoff should recover most sheds (served {}, shed {})",
            rep.served,
            rep.shed
        );
        assert_eq!(rep.to_json().get("retried").unwrap().as_u64(), Some(rep.retried as u64));
    }

    #[test]
    fn injected_engine_faults_reach_the_report() {
        use crate::exec::faults::{Fault, FaultPlan, FaultyEngine};
        // Second engine invocation panics; a single closed-loop client
        // means singleton batches, so exactly one request resolves as an
        // engine fault and the rest are served.
        let plan = FaultPlan::new().with(1, Fault::Panic);
        let mut router = Router::new();
        router.register(ModelVariant::new("m", Arc::new(FaultyEngine::new(Echo, plan))));
        let server = Server::start(router, ServerConfig::default());
        let h = server.handle();
        let rep = run(&h, "m", &LoadSpec::closed(1, 10, 9)).unwrap();
        assert_eq!(rep.issued, 10);
        assert_eq!(rep.faulted, 1, "the poisoned request got an EngineFault reply");
        assert_eq!(rep.served, 9, "every other request served normally");
        assert_eq!(rep.engine_faults, 1, "metrics delta captured in the report");
        let j = rep.to_json();
        assert_eq!(j.get("faulted").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("engine_faults").unwrap().as_u64(), Some(1));
    }
}
